//! The legacy fixed-`dt` time-stepped co-simulation loop.
//!
//! Retained **temporarily** as the golden reference for the event-driven
//! timeline engine (`crate::timeline`): the golden suite in
//! `desync::golden` pins the event engine's traces against this stepper,
//! and `repro bench` (with the `legacy-stepper` feature) records the
//! speedup. The logic is the seed implementation, unchanged — scheduled
//! for removal once the event engine has survived a few releases.
//!
//! Only compiled under `cfg(test)` or the `legacy-stepper` cargo feature.

use std::collections::HashMap;

use crate::desync::noise::NoiseStream;
use crate::desync::program::{Phase, Program, SyncKind};
use crate::desync::trace::{PhaseRecord, TraceLog};
use crate::desync::{CoSimConfig, CoSimResult};
use crate::kernels::KernelId;
use crate::sharing::{share_multigroup, KernelGroup};

#[derive(Debug, Clone, PartialEq)]
enum RankState {
    /// Waiting for its staggered start.
    NotStarted,
    /// Between phases; next phase is `flat` (sync not yet satisfied).
    Ready { flat: usize },
    /// Running a kernel phase.
    Running { flat: usize, kernel: KernelId, remaining: f64, started: f64 },
    /// Arrived at a collective, waiting for the others.
    Collective { flat: usize, arrived: f64 },
    /// Idling until `until` (explicit Idle phase or noise).
    Idling { flat: Option<usize>, until: f64, resume: Box<RankState>, started: f64 },
    /// Program complete.
    Done,
}

/// Is the sync precondition of phase `flat` satisfied for rank `r`?
fn sync_ok(
    sync: SyncKind,
    r: usize,
    flat: usize,
    completed: &[i64],
    n: usize,
    neighbor_radius: usize,
) -> bool {
    match sync {
        SyncKind::None => true,
        SyncKind::Global => true, // handled by the collective machinery
        SyncKind::Neighbors => {
            if flat == 0 {
                return true;
            }
            let prev = flat as i64 - 1;
            let radius = neighbor_radius.min(n / 2);
            (1..=radius).all(|k| {
                completed[(r + n - k) % n] >= prev && completed[(r + k) % n] >= prev
            })
        }
    }
}

/// Run the time-stepped co-simulation (the seed `CoSimEngine::run`).
///
/// `chars` maps each program kernel to its `(f, b_s[GB/s])`
/// characterization.
pub fn run_stepped(
    program: &Program,
    n_ranks: usize,
    config: &CoSimConfig,
    chars: &HashMap<KernelId, (f64, f64)>,
) -> CoSimResult {
    let n = n_ranks;
    let dt = config.dt_s;
    let mut t = 0.0f64;
    let mut states: Vec<RankState> = (0..n).map(|_| RankState::NotStarted).collect();
    let mut completed_upto: Vec<i64> = vec![-1; n]; // last completed flat index
    let mut trace = TraceLog::default();
    let mut finish = vec![f64::NAN; n];
    let mut noise: Vec<NoiseStream> = (0..n).map(|r| config.noise.stream(r)).collect();
    // Collective instance -> (ranks arrived, all-arrived time).
    let mut collectives: HashMap<usize, (usize, f64)> = HashMap::new();
    // Memoized sharing-model evaluations by group composition.
    let mut share_cache: HashMap<Vec<(KernelId, usize)>, HashMap<KernelId, f64>> = HashMap::new();
    let mut steps: u64 = 0;

    let total = program.total_phases();
    while t < config.t_max_s && states.iter().any(|s| *s != RankState::Done) {
        steps += 1;
        // 1. Start transitions.
        for r in 0..n {
            loop {
                match states[r].clone() {
                    RankState::NotStarted => {
                        if t >= r as f64 * config.initial_stagger_s {
                            states[r] = RankState::Ready { flat: 0 };
                        } else {
                            break;
                        }
                    }
                    RankState::Ready { flat } => {
                        if flat >= total {
                            states[r] = RankState::Done;
                            finish[r] = t;
                            break;
                        }
                        match program.phase(flat).unwrap().clone() {
                            Phase::Kernel { kernel: k, volume_bytes, sync, .. } => {
                                if sync_ok(sync, r, flat, &completed_upto, n, config.neighbor_radius) {
                                    states[r] = RankState::Running {
                                        flat,
                                        kernel: k,
                                        remaining: volume_bytes,
                                        started: t,
                                    };
                                }
                                break;
                            }
                            Phase::Allreduce { .. } => {
                                let e = collectives.entry(flat).or_insert((0, f64::NAN));
                                e.0 += 1;
                                if e.0 == n {
                                    e.1 = t; // all arrived
                                }
                                states[r] = RankState::Collective { flat, arrived: t };
                                break;
                            }
                            Phase::Idle { duration_s, .. } => {
                                states[r] = RankState::Idling {
                                    flat: Some(flat),
                                    until: t + duration_s,
                                    resume: Box::new(RankState::Ready { flat: flat + 1 }),
                                    started: t,
                                };
                                break;
                            }
                        }
                    }
                    _ => break,
                }
            }
        }

        // 2. Bandwidth sharing among running kernel ranks. The group
        // composition changes only at phase boundaries (rarely relative
        // to dt), so evaluations are memoized by composition.
        let mut composition: Vec<(KernelId, usize)> = Vec::new();
        for s in &states {
            if let RankState::Running { kernel: k, .. } = s {
                match composition.iter_mut().find(|(kk, _)| kk == k) {
                    Some((_, cnt)) => *cnt += 1,
                    None => composition.push((*k, 1)),
                }
            }
        }
        composition.sort_by_key(|(k, _)| k.key());
        let per_core: &HashMap<KernelId, f64> =
            share_cache.entry(composition.clone()).or_insert_with(|| {
                let groups: Vec<KernelGroup> = composition
                    .iter()
                    .map(|(k, n)| {
                        let (f, bs) = chars[k];
                        KernelGroup { n: *n, f, bs_gbs: bs }
                    })
                    .collect();
                let share = share_multigroup(&groups);
                composition
                    .iter()
                    .zip(&share.groups)
                    .map(|((k, _), e)| (*k, e.per_core_gbs * 1e9)) // bytes/s
                    .collect()
            });

        // 3. Advance.
        for r in 0..n {
            match states[r].clone() {
                RankState::Running { flat, kernel: k, mut remaining, started } => {
                    // Noise can preempt the kernel.
                    if let Some(dur) = noise[r].poll(t, dt) {
                        states[r] = RankState::Idling {
                            flat: None,
                            until: t + dur,
                            resume: Box::new(RankState::Running { flat, kernel: k, remaining, started }),
                            started: t,
                        };
                        continue;
                    }
                    remaining -= per_core[&k] * dt;
                    if remaining <= 0.0 {
                        let phase = program.phase(flat).unwrap();
                        trace.records.push(PhaseRecord {
                            rank: r,
                            iteration: flat / program.phases.len(),
                            label: phase.label(),
                            t_start: started,
                            t_end: t + dt,
                        });
                        completed_upto[r] = flat as i64;
                        states[r] = RankState::Ready { flat: flat + 1 };
                    } else {
                        states[r] = RankState::Running { flat, kernel: k, remaining, started };
                    }
                }
                RankState::Collective { flat, arrived } => {
                    let (count, all_at) = collectives[&flat];
                    if count == n && !all_at.is_nan() {
                        let cost = match program.phase(flat).unwrap() {
                            Phase::Allreduce { cost_s, .. } => *cost_s,
                            _ => 0.0,
                        };
                        if t >= all_at + cost {
                            let phase = program.phase(flat).unwrap();
                            trace.records.push(PhaseRecord {
                                rank: r,
                                iteration: flat / program.phases.len(),
                                label: phase.label(),
                                t_start: arrived,
                                t_end: t,
                            });
                            completed_upto[r] = flat as i64;
                            states[r] = RankState::Ready { flat: flat + 1 };
                        }
                    }
                }
                RankState::Idling { flat, until, resume, started } => {
                    if t >= until {
                        if let Some(fl) = flat {
                            let phase = program.phase(fl).unwrap();
                            trace.records.push(PhaseRecord {
                                rank: r,
                                iteration: fl / program.phases.len(),
                                label: phase.label(),
                                t_start: started,
                                t_end: t,
                            });
                            completed_upto[r] = fl as i64;
                        }
                        states[r] = *resume;
                    }
                }
                _ => {}
            }
        }

        t += dt;
    }

    CoSimResult {
        trace,
        finish_s: finish,
        t_end_s: t,
        events: steps,
        stats: crate::desync::SimStats::default(),
    }
}
