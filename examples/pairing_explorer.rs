//! Pairing explorer: sweep a kernel pairing across every thread split and
//! machine, Fig. 6/7-style.
//!
//! ```bash
//! cargo run --release --example pairing_explorer -- dcopy ddot2 [full|sym]
//! ```

use membw::config::{machine, MachineId};
use membw::kernels::{kernel, KernelId};
use membw::sweep::{full_domain_splits, run_cases, symmetric_splits, MeasureEngine};

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let k1 = KernelId::parse(args.first().map(String::as_str).unwrap_or("dcopy")).expect("kernel 1");
    let k2 = KernelId::parse(args.get(1).map(String::as_str).unwrap_or("ddot2")).expect("kernel 2");
    let symmetric = args.get(2).map(String::as_str) == Some("sym");

    println!(
        "pairing {} + {} — {} splits\n",
        kernel(k1).name,
        kernel(k2).name,
        if symmetric { "symmetric (Fig. 7)" } else { "full-domain (Fig. 6)" }
    );

    for mid in MachineId::ALL {
        let m = machine(mid);
        let cases = if symmetric {
            symmetric_splits(&m, k1, k2)
        } else {
            full_domain_splits(&m, k1, k2)
        };
        let rs = run_cases(&m, &cases, &MeasureEngine::Fluid).expect("sweep");
        println!("[{}] {} ({} cores)", mid.key(), m.name, m.cores);
        println!("  n1  n2 | meas/core I  model I | meas/core II  model II | total  | stacked share I");
        for c in &rs.cases {
            let share = c.measured_per_core[0] * c.n[0] as f64 / c.measured_total;
            let bar = "#".repeat((share * 30.0).round() as usize);
            println!(
                "  {:2}  {:2} | {:7.2}  {:7.2} | {:8.2}  {:8.2} | {:6.1} | {:<30}",
                c.n[0],
                c.n[1],
                c.measured_per_core[0],
                c.model_per_core[0],
                c.measured_per_core[1],
                c.model_per_core[1],
                c.measured_total,
                bar
            );
        }
        let errs = rs.all_errors();
        let max = errs.iter().cloned().fold(0.0, f64::max);
        println!("  max model error: {:.2}%\n", max * 100.0);
    }
}
