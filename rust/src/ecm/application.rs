//! The ECM *application model*: per-kernel, per-machine cycle contributions.
//!
//! All times are cycles per **unit** = one cache line of iterations
//! (8 double elements).

use crate::config::{LlcKind, Machine};
use crate::kernels::KernelSignature;

/// Cycle contributions of one kernel on one machine (ECM application model).
#[derive(Debug, Clone, Copy)]
pub struct ApplicationModel {
    /// In-core (arithmetic) execution time that overlaps with everything.
    pub t_ol: f64,
    /// Load-instruction retirement time (only loads count on the modeled
    /// machines; stores retire in parallel).
    pub t_l1reg: f64,
    /// L1↔L2 transfer time.
    pub t_l1l2: f64,
    /// L2↔L3 transfer time (victim-LLC adjusted).
    pub t_l2l3: f64,
    /// Memory transfer time at the kernel's saturated bandwidth.
    pub t_mem: f64,
    /// Per-line latency residue not hidden by prefetching (limited MLP) —
    /// calibration extension of the textbook model, see `Machine`.
    pub t_lat: f64,
    /// Memory lines per unit.
    pub mem_lines: f64,
    /// Write fraction of the memory traffic.
    pub write_frac: f64,
    /// Concurrent address streams at the memory interface.
    pub streams: usize,
}

/// Effective L2↔L3 cache lines per unit, accounting for the LLC
/// organization:
///
/// * **Inclusive** (BDW): every memory line also crosses L2↔L3 — the full
///   `l3` stream count applies.
/// * **Victim** (CLX, Rome): memory-sourced reads and RFOs go directly to
///   L2, bypassing the LLC; only L3-resident reuse reads (stencil rows) and
///   dirty write-backs cross L2↔L3.
pub fn effective_l3_lines(k: &KernelSignature, m: &Machine) -> f64 {
    match m.llc {
        LlcKind::Inclusive => k.l3.total() as f64,
        LlcKind::Victim => {
            let reuse_reads = k.l3.reads.saturating_sub(k.mem.reads);
            (reuse_reads + k.l3.writes) as f64
        }
    }
}

impl ApplicationModel {
    /// Build the application model of kernel `k` on machine `m`.
    pub fn new(k: &KernelSignature, m: &Machine) -> Self {
        let lanes = m.simd_bytes as f64 / 8.0; // doubles per SIMD register
        let iters = crate::ELEMS_PER_LINE as f64;

        // Arithmetic: 2 FMA ports x `lanes` x 2 flops each.
        let flops_per_cy = 2.0 * lanes * 2.0;
        let t_ol = iters * k.flops_per_iter as f64 / flops_per_cy;

        // Load instructions per unit, SIMD-packed.
        let load_instr = (iters * k.loads_per_iter as f64 / lanes).ceil();
        let t_l1reg = load_instr / m.ld_per_cy;

        let t_l1l2 = k.l2.total() as f64 * m.line_cycles(m.l1l2_bpc);
        let t_l2l3 = effective_l3_lines(k, m) * m.line_cycles(m.l2l3_bpc);

        let mem_lines = k.mem.total() as f64;
        let write_frac = k.write_frac();
        let streams = k.mem.total();
        let bs_bpc = m.saturated_bw(write_frac, streams) / m.freq_ghz; // bytes/cy
        let t_mem = mem_lines * crate::CACHE_LINE_BYTES / bs_bpc;
        // Only latency-critical lines pay the MLP residue: on Intel the
        // store buffers hide write-back latency; on Rome all lines share
        // the single L2<->mem port.
        let residue_lines = if m.residue_on_all_lines {
            k.mem.total()
        } else {
            k.mem.reads + k.mem.rfo
        } as f64;
        let t_lat = m.latency_residue_cy * residue_lines;

        ApplicationModel {
            t_ol,
            t_l1reg,
            t_l1l2,
            t_l2l3,
            t_mem,
            t_lat,
            mem_lines,
            write_frac,
            streams,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{machine, MachineId};
    use crate::kernels::{kernel, KernelId};

    #[test]
    fn victim_llc_drops_streaming_l3_read_traffic() {
        let stream = kernel(KernelId::Stream);
        let bdw = machine(MachineId::Bdw1);
        let clx = machine(MachineId::Clx);
        assert_eq!(effective_l3_lines(&stream, &bdw), 4.0);
        assert_eq!(effective_l3_lines(&stream, &clx), 1.0); // write-back only
    }

    #[test]
    fn victim_llc_keeps_stencil_reuse_traffic() {
        let jac = kernel(KernelId::JacobiV1L3); // 3R+1W+1RFO at L3
        let clx = machine(MachineId::Clx);
        // 2 reuse reads (3 total - 1 from memory) + 1 write-back.
        assert_eq!(effective_l3_lines(&jac, &clx), 3.0);
    }

    #[test]
    fn stream_contributions_on_bdw1() {
        let am = ApplicationModel::new(&kernel(KernelId::Stream), &machine(MachineId::Bdw1));
        assert!((am.t_l1reg - 2.0).abs() < 1e-9); // 4 AVX2 loads / 2 per cy
        assert!((am.t_l1l2 - 4.0).abs() < 1e-9); // 4 lines at 64 B/cy
        assert!((am.t_l2l3 - 8.0).abs() < 1e-9); // 4 lines at 32 B/cy
        assert!(am.t_mem > 10.0 && am.t_mem < 11.5); // ~10.6 cy at 53.2 GB/s
        assert!(am.t_ol < am.t_l1reg + am.t_l1l2 + am.t_l2l3 + am.t_mem);
    }
}
