//! Exact event simulation on the shared contention timeline.
//!
//! Between events, every running rank's remaining data volume drains at the
//! constant per-core rate the multigroup sharing model assigns to its
//! kernel's group, so the next phase completion is solved in closed form
//! instead of being stepped to. The engine therefore has *no* time step and
//! no discretization error: its output is the exact `dt → 0` limit of the
//! legacy stepper (pinned by the golden suite in `desync::golden`).
//!
//! Per-rank progress is tracked through per-kernel *drained-bytes
//! integrals*: `B_k(t) = ∫ rate_k dt` advances only when rates change
//! (O(#kernels), not O(#ranks)), and a rank running kernel `k` since `t₀`
//! with volume `V` completes when `B_k` reaches the *target* `B_k(t₀) + V`.
//! Ranks of one group complete in target order, so each group keeps a
//! min-heap of targets, and the earliest projected crossing over all groups
//! is a single closed-form time (`t_complete`) compared against the event
//! queue's head — a completion is an *event*, but never a heap entry, so a
//! composition change costs O(#kernels) instead of queue churn.
//!
//! **ccNUMA topologies**: all contention state — group counts, integrals,
//! rates, completion heaps, the memoized sharing model itself — is keyed by
//! `(domain, kernel)`; each domain runs its own contention timeline over
//! its resident ranks ([`simulate_placed`]) and only the event queue is
//! shared. The single-domain [`simulate`] is the degenerate
//! [`RankLayout::single`] case, bit-identical to the pre-topology engine
//! (pinned by the topology conformance suite).
//!
//! # Cluster scaling
//!
//! Three properties keep per-event cost independent of the topology size,
//! so hundreds of nodes simulate interactively (`repro bench`,
//! `BENCH_cluster.json`):
//!
//! * **Incremental re-rating.** Remote traffic couples the interfaces of
//!   one *node*, never of the whole cluster: a cluster layout
//!   ([`RankLayout::node_of`]) partitions its domains into identical
//!   nodes, and drain rates are a pure function of the node's own group
//!   composition. A refresh therefore re-rates only nodes whose
//!   composition actually changed (`dirty` per domain, scoped per node) —
//!   the historical path re-rated *every* domain of the shape on any
//!   change. Within a re-rated node a composition fingerprint
//!   (bitwise rate comparison against the memoized pure function) decides
//!   which domains re-project their completion times; clean domains keep
//!   their analytic projections, which stay valid because their integrals
//!   advance at unchanged rates. [`RatingMode::FullRecompute`] retains the
//!   every-node rating as a benchmark reference; both modes are pinned
//!   bit-identical (same pure rates, same projections).
//! * **Flat index-keyed state.** Integrals, counts, and rates are flat
//!   `(domain, kernel)`-indexed arrays sized by the [`RankLayout`];
//!   completion-heap entries and queue events are packed `u128` keys
//!   (see [`crate::timeline::event`]); collective arrival counters are a
//!   flat per-phase array. No per-event allocation, no pointer chasing.
//! * **Lazy per-domain integral folding.** Integrals advance only when
//!   *observed* — at a completion, a composition change, or a rate change
//!   in their own domain — so an event touches O(affected domains ×
//!   kernels) state, not O(all domains × kernels).
//!
//! # Checkpoint / resume
//!
//! [`simulate_placed_until`] runs the same loop but stops once the next
//! event would land past a stop time, returning an [`EngineCheckpoint`]
//! that owns the complete mutable state; [`resume_placed`] continues from
//! it. The pause check only *reads* the next completion time and the
//! queue head, so a paused-and-resumed run is bit-identical to an
//! uninterrupted [`simulate_placed`] (the `repro serve` makespan probe
//! leans on this to advance a fleet simulation incrementally across
//! requests).

use std::cmp::Reverse;
use std::collections::BinaryHeap;

use crate::desync::{CoSimConfig, CoSimResult, Phase, Program, SimStats, SyncKind, TraceLog};
use crate::desync::{NoiseStream, PhaseRecord};
use crate::kernels::KernelId;
use crate::sharing::{RemoteRateModel, ShareCache, TopoShape};
use crate::timeline::event::{EventKind, EventQueue};
use crate::topology::RankLayout;

/// Relative completion slack on the drained-bytes integrals: absorbs the
/// floating-point residue of `target - B_k` at the projected crossing (a few
/// ulp; the slack corresponds to sub-nanosecond simulated time at GB/s
/// rates).
const EPS_REL: f64 = 1e-9;

/// How the coupled remote-rate path re-rates on a composition change.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub enum RatingMode {
    /// Re-rate only nodes with a dirty domain (the production path).
    #[default]
    Incremental,
    /// Re-rate every node on every refresh — the retained reference the
    /// incremental path is benchmarked against and pinned bit-identical to
    /// (rates are pure functions of the node composition, so skipping a
    /// clean node can never change a result).
    FullRecompute,
}

/// How an idling rank resumes.
#[derive(Debug, Clone, Copy, PartialEq)]
enum Resume {
    /// Proceed to phase `flat` (after an explicit `Phase::Idle`).
    Next { flat: usize },
    /// Re-enter an interrupted kernel with `remaining` bytes to go.
    Kernel { flat: usize, slot: usize, remaining: f64, started: f64 },
}

#[derive(Debug, Clone, Copy, PartialEq)]
enum RankState {
    /// Waiting for its staggered start.
    NotStarted,
    /// Between phases; next phase is `flat` (sync not yet satisfied).
    Ready { flat: usize },
    /// Inside a kernel: completes when the slot's integral reaches `target`.
    Running { flat: usize, slot: usize, target: f64, started: f64 },
    /// Arrived at a collective, waiting for the release event.
    Collective { flat: usize, arrived: f64 },
    /// Idling until `until` (explicit Idle phase or noise interruption).
    Idling { flat: Option<usize>, until: f64, started: f64, resume: Resume },
    /// Program complete.
    Done,
}

/// Pre-resolved per-phase execution info (one entry per phase of an
/// iteration; labels stay in the [`Program`]).
#[derive(Debug, Clone, Copy)]
enum PhaseInfo {
    Kernel { slot: usize, volume: f64, sync: SyncKind },
    Allreduce { cost: f64 },
    Idle { duration: f64 },
}

/// Pack a completion-heap entry into one `u128` whose ascending numeric
/// order is `(target, rank, ver)` — targets are non-negative finite (the
/// integrals only grow), which is the range where `f64::to_bits` is
/// order-preserving. `ver` participates only for exact `(target, rank)`
/// duplicates, where any order is correct (at most one entry is live).
#[inline]
fn pack_entry(target: f64, rank: usize, ver: u64) -> u128 {
    debug_assert!(target.is_finite() && target >= 0.0, "completion target {target}");
    debug_assert!(rank < (1usize << 32), "rank {rank} exceeds the 32-bit entry field");
    ((target.to_bits() as u128) << 64) | ((rank as u128) << 32) | ((ver as u32) as u128)
}

/// `(target, rank, ver)` of a packed completion-heap entry.
#[inline]
fn entry_parts(key: u128) -> (f64, usize, u32) {
    (f64::from_bits((key >> 64) as u64), ((key >> 32) & 0xFFFF_FFFF) as usize, key as u32)
}

struct Sim<'a> {
    program: &'a Program,
    infos: Vec<PhaseInfo>,
    n: usize,
    total: usize,
    radius: usize,
    t_max: f64,
    stagger: f64,
    mode: RatingMode,

    states: Vec<RankState>,
    completed: Vec<i64>,
    trace: TraceLog,
    finish: Vec<f64>,
    noise: Vec<NoiseStream>,
    /// Ranks arrived so far, per collective flat phase index.
    collective_arrived: Vec<u32>,

    queue: EventQueue,
    /// One memoized sharing model per ccNUMA domain (domains contend
    /// independently; a scaled domain's cache carries its scaled b_s).
    share: Vec<ShareCache>,
    /// The coupled remote-access rate model, when the layout carries a
    /// nonzero remote fraction. Remote traffic couples the interfaces of
    /// one *node*, so the model is built on the per-node sub-shape and
    /// evaluated once per dirty node (identical nodes share its
    /// composition memo).
    remote: Option<RemoteRateModel>,
    /// Kernel slots per domain.
    nk: usize,
    /// Number of ccNUMA domains.
    nd: usize,
    /// Cluster nodes (1 unless the layout carries a node partition and
    /// remote traffic is active).
    n_nodes: usize,
    /// Domains per node (`nd` when `n_nodes == 1`).
    dpn: usize,
    /// Domain of each rank.
    domain_of: Vec<usize>,
    /// Cores currently running each (domain, kernel) slot; `d * nk + k`.
    counts: Vec<u16>,
    /// Drained-bytes integral per (domain, kernel) slot.
    integral: Vec<f64>,
    /// Current per-core drain rate per slot, bytes/s.
    rates: Vec<f64>,
    /// Per domain: time its integrals were last folded forward (lazy
    /// folding — an untouched domain's integrals advance closed-form).
    t_fold: Vec<f64>,
    /// Per domain: composition changed since the last refresh.
    dirty: Vec<bool>,
    /// Per domain: the analytic next-completion time under the current
    /// composition.
    t_complete: Vec<f64>,
    /// Per-rank guard for lazily dropped group-heap entries.
    run_ver: Vec<u64>,
    /// Per-slot completion FIFOs over packed `(target, rank, ver)` keys.
    groups: Vec<BinaryHeap<Reverse<u128>>>,
    /// Scratch: one node's freshly rated slots (borrow decoupling).
    scratch_rates: Vec<f64>,
    /// Scratch: domains whose projected completion fires at the current
    /// instant.
    due: Vec<usize>,
    /// Scratch: ranks whose `completed` advanced during the current event.
    wake: Vec<usize>,
    /// Scratch: the deduplicated halo-neighbourhood of `wake`.
    wake_set: Vec<usize>,
    events: u64,
    stats: SimStats,
    /// Simulated time of the last processed event (the eventual
    /// `t_end_s`; survives a pause/resume cycle via the checkpoint).
    t_end: f64,
}

/// Run the event-driven co-simulation on a single contention domain (the
/// degenerate [`RankLayout::single`] case of [`simulate_placed`]).
///
/// `chars` holds `(kernel, f, b_s[GB/s])` for every kernel the program
/// references. `config.dt_s` is ignored — the event engine has no step.
pub fn simulate(
    program: &Program,
    n_ranks: usize,
    config: &CoSimConfig,
    chars: &[(KernelId, f64, f64)],
) -> CoSimResult {
    simulate_placed(program, n_ranks, config, chars, &RankLayout::single(n_ranks))
}

/// Run the event-driven co-simulation on a multi-domain topology.
///
/// `layout` assigns every rank to a ccNUMA domain (see
/// [`crate::topology::Placement::rank_layout`]); each domain drains its
/// resident ranks against its own memory interface — `layout.n_domains`
/// concurrent contention timelines over one shared event queue. A domain
/// with bandwidth scale `s` evaluates the sharing model against `s·b_s`.
///
/// When the layout carries a nonzero remote-access fraction
/// ([`RankLayout::with_remote`]), drain rates come from the coupled
/// remote model instead ([`crate::sharing::RemoteRateModel`]): each rank's
/// stream splits over its home domain, the remote domains of its *node*,
/// and the inter-socket links, and a composition change re-evaluates the
/// affected node (see the module docs on incremental re-rating). On a
/// cluster layout ([`RankLayout::node_of`] non-uniform) the nodes must be
/// identical — same socket pattern, bandwidth scales, and remote fractions
/// per node — and remote traffic never leaves a node; nodes couple only
/// through collectives. Collective releases additionally pay the layout's
/// inter-socket barrier latency (`collective_extra_s`; zero on
/// single-socket layouts). An all-zero remote spec is normalized away,
/// keeping the independent per-domain path bit-identical (pinned by the
/// topology conformance suite).
pub fn simulate_placed(
    program: &Program,
    n_ranks: usize,
    config: &CoSimConfig,
    chars: &[(KernelId, f64, f64)],
    layout: &RankLayout,
) -> CoSimResult {
    simulate_placed_mode(program, n_ranks, config, chars, layout, RatingMode::Incremental)
}

/// [`simulate_placed`] with an explicit [`RatingMode`] — the
/// `FullRecompute` reference exists for benchmarking and for pinning the
/// incremental path (`repro bench` reports the speedup between the two).
pub fn simulate_placed_mode(
    program: &Program,
    n_ranks: usize,
    config: &CoSimConfig,
    chars: &[(KernelId, f64, f64)],
    layout: &RankLayout,
    mode: RatingMode,
) -> CoSimResult {
    match simulate_placed_until(program, n_ranks, config, chars, layout, mode, f64::INFINITY) {
        SimStep::Done(r) => r,
        SimStep::Paused(_) => unreachable!("an unbounded run cannot pause"),
    }
}

/// Outcome of one bounded stretch of simulation.
pub enum SimStep {
    /// The program ran to completion (or hit `t_max_s`); the result is
    /// final.
    Done(CoSimResult),
    /// Simulated time reached `t_stop` with work pending. Resume with
    /// [`resume_placed`].
    Paused(EngineCheckpoint),
}

/// The complete mutable engine state of a paused run.
///
/// Opaque by design: the only valid use is handing it back to
/// [`resume_placed`] with the *same* program, config, characterizations,
/// layout, and rating mode (basic dimension mismatches panic; semantic
/// mismatches are the caller's contract). The checkpoint owns every
/// mutable piece of the engine — rank states, the event queue (cloning a
/// `BinaryHeap` preserves its internal layout, so a resumed run pops the
/// exact same sequence), the per-slot completion heaps, drained-bytes
/// integrals, noise RNG streams — so a paused-and-resumed run is
/// bit-identical to an uninterrupted one (pinned in
/// `tests/service_conformance.rs`). The memoized sharing models are *not*
/// checkpointed: they are pure composition → rate memos, rebuilt empty on
/// resume, which changes the `share_*`/`remote_*` cache counters (they
/// then cover only the final segment) but never a rate.
#[derive(Clone)]
pub struct EngineCheckpoint {
    n: usize,
    nd: usize,
    nk: usize,
    total: usize,
    states: Vec<RankState>,
    completed: Vec<i64>,
    trace: TraceLog,
    finish: Vec<f64>,
    noise: Vec<NoiseStream>,
    collective_arrived: Vec<u32>,
    queue: EventQueue,
    counts: Vec<u16>,
    integral: Vec<f64>,
    rates: Vec<f64>,
    t_fold: Vec<f64>,
    dirty: Vec<bool>,
    t_complete: Vec<f64>,
    run_ver: Vec<u64>,
    groups: Vec<BinaryHeap<Reverse<u128>>>,
    events: u64,
    stats: SimStats,
    t_end: f64,
}

impl EngineCheckpoint {
    /// Simulated time of the last processed event.
    pub fn t_end(&self) -> f64 {
        self.t_end
    }

    /// Events processed so far.
    pub fn events(&self) -> u64 {
        self.events
    }
}

/// How [`Sim::run_until`] stopped.
enum StepEnd {
    Finished,
    Paused,
}

/// [`simulate_placed_mode`], but stop once the next event would land
/// past `t_stop` (events at exactly `t_stop` still fire). Returns the
/// final result if the program finished first, otherwise a resumable
/// [`EngineCheckpoint`]. `t_stop = ∞` never pauses — this is exactly the
/// code path of [`simulate_placed`].
#[allow(clippy::too_many_arguments)]
pub fn simulate_placed_until(
    program: &Program,
    n_ranks: usize,
    config: &CoSimConfig,
    chars: &[(KernelId, f64, f64)],
    layout: &RankLayout,
    mode: RatingMode,
    t_stop: f64,
) -> SimStep {
    let mut sim = build_sim(program, n_ranks, config, chars, layout, mode);
    sim.seed();
    drive(sim, t_stop)
}

/// Resume a paused run from its checkpoint up to a new `t_stop`. The
/// caller must pass the same program, config, characterizations, layout,
/// and mode the checkpoint was taken under.
#[allow(clippy::too_many_arguments)]
pub fn resume_placed(
    program: &Program,
    n_ranks: usize,
    config: &CoSimConfig,
    chars: &[(KernelId, f64, f64)],
    layout: &RankLayout,
    mode: RatingMode,
    cp: EngineCheckpoint,
    t_stop: f64,
) -> SimStep {
    let mut sim = build_sim(program, n_ranks, config, chars, layout, mode);
    sim.restore(cp);
    drive(sim, t_stop)
}

fn drive(mut sim: Sim<'_>, t_stop: f64) -> SimStep {
    match sim.run_until(t_stop) {
        StepEnd::Finished => SimStep::Done(sim.finalize()),
        StepEnd::Paused => SimStep::Paused(sim.checkpoint()),
    }
}

/// Validate the inputs and assemble a fresh (un-seeded) engine.
fn build_sim<'a>(
    program: &'a Program,
    n_ranks: usize,
    config: &CoSimConfig,
    chars: &[(KernelId, f64, f64)],
    layout: &RankLayout,
    mode: RatingMode,
) -> Sim<'a> {
    let nd = layout.n_domains;
    assert_eq!(layout.rank_domain.len(), n_ranks, "layout must place every rank");
    assert_eq!(layout.bw_scale.len(), nd, "layout must scale every domain");
    assert_eq!(layout.node_of.len(), nd, "layout must assign every domain to a node");
    assert!(layout.rank_domain.iter().all(|&d| d < nd), "rank placed on missing domain");
    let remote_active = layout
        .remote
        .as_ref()
        .is_some_and(|r| r.frac.iter().any(|&f| f > 0.0));
    let (remote, n_nodes, dpn) = if remote_active {
        let spec = layout.remote.as_ref().expect("checked above");
        assert_eq!(spec.frac.len(), nd, "remote spec must cover every domain");
        assert_eq!(layout.socket_of.len(), nd, "remote layouts must map domains to sockets");
        let n_nodes = layout.n_nodes();
        let (n_nodes, dpn) = if n_nodes > 1 {
            // Cluster layouts must be node-major and node-uniform: the
            // per-node rate model (and its composition memo, shared by all
            // nodes) is only a valid pure function of a node's composition
            // when every node presents the same interface network.
            assert_eq!(nd % n_nodes, 0, "node partition must divide the domains evenly");
            let dpn = nd / n_nodes;
            for (d, &node) in layout.node_of.iter().enumerate() {
                assert_eq!(node, d / dpn, "cluster layouts must be node-major");
            }
            for i in 1..n_nodes {
                let off = layout.socket_of[i * dpn] - layout.socket_of[0];
                for j in 0..dpn {
                    assert_eq!(
                        layout.socket_of[i * dpn + j],
                        layout.socket_of[j] + off,
                        "cluster nodes must share one socket pattern"
                    );
                    assert_eq!(
                        layout.bw_scale[i * dpn + j].to_bits(),
                        layout.bw_scale[j].to_bits(),
                        "cluster nodes must share one bandwidth profile"
                    );
                    assert_eq!(
                        spec.frac[i * dpn + j].to_bits(),
                        spec.frac[j].to_bits(),
                        "cluster nodes must share one remote-traffic profile"
                    );
                }
            }
            (n_nodes, dpn)
        } else {
            (1, nd)
        };
        let socket_base = layout.socket_of[0];
        let model = RemoteRateModel::new(
            TopoShape {
                socket_of: layout.socket_of[..dpn].iter().map(|&s| s - socket_base).collect(),
                bw_scale: layout.bw_scale[..dpn].to_vec(),
                link_bw_gbs: layout.link_bw_gbs,
                link_bw_rev_gbs: layout.link_bw_rev_gbs,
                // Timeline programs characterize kernels at the memory
                // level only (every slot is `GroupKind::Mem`, see
                // `RemoteRateModel::new`), so no portion ever routes to a
                // shared-L3 interface and the capacity is irrelevant; 0
                // keeps the shape's degenerate fixed point bit-identical.
                l3_bw_gbs: 0.0,
            },
            spec.frac[..dpn].to_vec(),
            chars.iter().map(|&(_, f, bs)| (f, bs)).collect(),
        );
        (Some(model), n_nodes, dpn)
    } else {
        (None, 1, nd)
    };
    let share: Vec<ShareCache> = layout
        .bw_scale
        .iter()
        .map(|&s| {
            if s == 1.0 {
                ShareCache::new(chars)
            } else {
                let scaled: Vec<(KernelId, f64, f64)> =
                    chars.iter().map(|&(k, f, bs)| (k, f, bs * s)).collect();
                ShareCache::new(&scaled)
            }
        })
        .collect();
    let nk = share[0].slots();
    let infos: Vec<PhaseInfo> = program
        .phases
        .iter()
        .map(|p| match p {
            Phase::Kernel { kernel, volume_bytes, sync, .. } => PhaseInfo::Kernel {
                slot: share[0].slot_of(*kernel).expect("program kernel not characterized"),
                volume: *volume_bytes,
                sync: *sync,
            },
            // Multi-socket layouts pay the inter-socket barrier hops on
            // every collective release (0.0 on single-socket layouts, so
            // the addition is bit-neutral there).
            Phase::Allreduce { cost_s, .. } => {
                PhaseInfo::Allreduce { cost: *cost_s + layout.collective_extra_s }
            }
            Phase::Idle { duration_s, .. } => PhaseInfo::Idle { duration: *duration_s },
        })
        .collect();

    let scratch_len = if remote.is_some() { dpn * nk } else { 0 };
    Sim {
        program,
        infos,
        n: n_ranks,
        total: program.total_phases(),
        radius: config.neighbor_radius,
        t_max: config.t_max_s,
        stagger: config.initial_stagger_s,
        mode,
        states: vec![RankState::NotStarted; n_ranks],
        completed: vec![-1; n_ranks],
        trace: TraceLog::default(),
        finish: vec![f64::NAN; n_ranks],
        noise: (0..n_ranks).map(|r| config.noise.stream(r)).collect(),
        collective_arrived: vec![0; program.total_phases()],
        queue: EventQueue::new(),
        share,
        remote,
        nk,
        nd,
        n_nodes,
        dpn,
        domain_of: layout.rank_domain.clone(),
        counts: vec![0; nd * nk],
        integral: vec![0.0; nd * nk],
        rates: vec![0.0; nd * nk],
        t_fold: vec![0.0; nd],
        dirty: vec![false; nd],
        t_complete: vec![f64::INFINITY; nd],
        run_ver: vec![0; n_ranks],
        groups: (0..nd * nk).map(|_| BinaryHeap::new()).collect(),
        scratch_rates: vec![0.0; scratch_len],
        due: Vec::new(),
        wake: Vec::new(),
        wake_set: Vec::new(),
        events: 0,
        stats: SimStats::default(),
        t_end: 0.0,
    }
}

impl Sim<'_> {
    fn info(&self, flat: usize) -> PhaseInfo {
        self.infos[flat % self.infos.len()]
    }

    fn label(&self, flat: usize) -> &'static str {
        self.program.phase(flat).expect("flat in range").label()
    }

    fn record(&mut self, rank: usize, flat: usize, t_start: f64, t_end: f64) {
        self.trace.records.push(PhaseRecord {
            rank,
            iteration: flat / self.infos.len(),
            label: self.label(flat),
            t_start,
            t_end,
        });
    }

    /// Is the sync precondition of phase `flat` satisfied for rank `r`?
    /// (Identical to the legacy stepper's rule.)
    fn sync_ok(&self, sync: SyncKind, r: usize, flat: usize) -> bool {
        match sync {
            SyncKind::None | SyncKind::Global => true,
            SyncKind::Neighbors => {
                if flat == 0 {
                    return true;
                }
                let n = self.n;
                let prev = flat as i64 - 1;
                let radius = self.radius.min(n / 2);
                (1..=radius).all(|k| {
                    self.completed[(r + n - k) % n] >= prev
                        && self.completed[(r + k) % n] >= prev
                })
            }
        }
    }

    /// Advance domain `d`'s drained-bytes integrals to `t` at the current
    /// rates. Lazy: called only when the domain is *observed* (a
    /// completion, a composition change, or a rate change there) — rates
    /// are constant between observations, so the closed-form advance is
    /// exact.
    fn fold_domain(&mut self, d: usize, t: f64) {
        let dt = t - self.t_fold[d];
        if dt > 0.0 {
            let lo = d * self.nk;
            for slot in lo..lo + self.nk {
                if self.counts[slot] > 0 {
                    self.integral[slot] += self.rates[slot] * dt;
                }
            }
        }
        self.t_fold[d] = t;
    }

    /// The earliest analytic completion time over all domains.
    fn next_complete(&self) -> f64 {
        self.t_complete.iter().cloned().fold(f64::INFINITY, f64::min)
    }

    /// The coupled-path half of [`Sim::refresh`]: re-rate dirty nodes
    /// through the per-node remote model. A domain whose freshly rated
    /// slots differ bitwise from its current rates is folded forward and
    /// marked dirty for re-projection; a domain whose rates are unchanged
    /// keeps its projection (its integrals advance at the same rates, so
    /// the projected crossing is still exact). `FullRecompute` rates every
    /// node regardless — bit-identical output, all savings forfeited.
    fn refresh_remote(&mut self, t: f64) {
        let nper = self.dpn * self.nk;
        for node in 0..self.n_nodes {
            let dlo = node * self.dpn;
            let node_dirty = self.dirty[dlo..dlo + self.dpn].iter().any(|&x| x);
            if !node_dirty {
                if self.mode == RatingMode::Incremental {
                    self.stats.node_rates_reused += 1;
                    continue;
                }
                // FullRecompute: pay for the clean node anyway.
            }
            let slo = dlo * self.nk;
            self.stats.rate_evals += 1;
            {
                let (scratch, remote) = (
                    &mut self.scratch_rates,
                    self.remote.as_mut().expect("remote refresh without a model"),
                );
                scratch.copy_from_slice(remote.rates_bytes(&self.counts[slo..slo + nper]));
            }
            for dd in 0..self.dpn {
                let d = dlo + dd;
                let a = dd * self.nk;
                let changed = (0..self.nk)
                    .any(|k| self.rates[slo + a + k].to_bits() != self.scratch_rates[a + k].to_bits());
                if !changed {
                    continue;
                }
                self.fold_domain(d, t);
                self.rates[slo + a..slo + a + self.nk]
                    .copy_from_slice(&self.scratch_rates[a..a + self.nk]);
                self.dirty[d] = true;
            }
        }
    }

    /// After a composition change: new rates + the closed-form time of the
    /// earliest projected target crossing (no queue traffic). Only dirty
    /// domains are re-evaluated — a composition change on one ccNUMA
    /// domain leaves every other domain's rates and projection untouched.
    /// With remote traffic the interfaces of a *node* are coupled, so a
    /// dirty domain re-rates its node (and only domains whose rates moved
    /// re-project) — see [`Sim::refresh_remote`].
    fn refresh(&mut self, t: f64) {
        if self.remote.is_some() {
            self.refresh_remote(t);
        }
        for d in 0..self.nd {
            if !self.dirty[d] {
                continue;
            }
            self.dirty[d] = false;
            self.fold_domain(d, t);
            self.t_complete[d] = f64::INFINITY;
            let lo = d * self.nk;
            let hi = lo + self.nk;
            if self.counts[lo..hi].iter().all(|&c| c == 0) {
                continue; // nothing running here: no rates, no completion
            }
            if self.remote.is_none() {
                self.rates[lo..hi]
                    .copy_from_slice(self.share[d].rates_bytes(&self.counts[lo..hi]));
            }
            for slot in lo..hi {
                if self.counts[slot] == 0 || self.rates[slot] <= 0.0 {
                    continue;
                }
                loop {
                    let key = match self.groups[slot].peek() {
                        Some(k) => k.0,
                        None => break,
                    };
                    let (target, rank, ver) = entry_parts(key);
                    if ver != self.run_ver[rank] as u32 {
                        self.groups[slot].pop(); // stale: rank left the group
                        continue;
                    }
                    let dt_c = (target - self.integral[slot]).max(0.0) / self.rates[slot];
                    self.t_complete[d] = self.t_complete[d].min(t + dt_c);
                    break;
                }
            }
        }
    }

    /// Put a rank into a kernel phase (or straight into a pending noise
    /// idle, matching the stepper's deferred poll semantics). `slot` is the
    /// rank's *global* `(domain, kernel)` slot.
    fn enter_running(
        &mut self,
        rank: usize,
        flat: usize,
        slot: usize,
        remaining: f64,
        started: f64,
        t: f64,
    ) {
        if self.noise[rank].enabled() && self.noise[rank].next_at() <= t {
            // Noise that queued up while the rank was not running fires now.
            let dur = self.noise[rank].fire(t);
            self.states[rank] = RankState::Idling {
                flat: None,
                until: t + dur,
                started: t,
                resume: Resume::Kernel { flat, slot, remaining, started },
            };
            self.queue.push(t + dur, EventKind::IdleEnd, rank);
            self.queue.push(self.noise[rank].next_at(), EventKind::Noise, rank);
            return;
        }
        self.fold_domain(slot / self.nk, t);
        let target = self.integral[slot] + remaining;
        self.run_ver[rank] += 1;
        self.states[rank] = RankState::Running { flat, slot, target, started };
        self.groups[slot].push(Reverse(pack_entry(target, rank, self.run_ver[rank])));
        self.counts[slot] += 1;
        self.dirty[slot / self.nk] = true;
    }

    /// Try to move a Ready rank into its next phase.
    fn try_start(&mut self, rank: usize, t: f64) {
        let flat = match self.states[rank] {
            RankState::Ready { flat } => flat,
            _ => return,
        };
        if flat >= self.total {
            self.states[rank] = RankState::Done;
            self.finish[rank] = t;
            return;
        }
        match self.info(flat) {
            PhaseInfo::Kernel { slot, volume, sync } => {
                if self.sync_ok(sync, rank, flat) {
                    let slot_g = self.domain_of[rank] * self.nk + slot;
                    self.enter_running(rank, flat, slot_g, volume, t, t);
                }
            }
            PhaseInfo::Allreduce { cost } => {
                let arrived = &mut self.collective_arrived[flat];
                *arrived += 1;
                let all = *arrived as usize == self.n;
                self.states[rank] = RankState::Collective { flat, arrived: t };
                if all {
                    self.queue.push(t + cost, EventKind::CollectiveRelease, flat);
                }
            }
            PhaseInfo::Idle { duration } => {
                self.states[rank] = RankState::Idling {
                    flat: Some(flat),
                    until: t + duration,
                    started: t,
                    resume: Resume::Next { flat: flat + 1 },
                };
                self.queue.push(t + duration, EventKind::IdleEnd, rank);
            }
        }
    }

    /// Retry every Ready rank (collective releases advance everyone, so
    /// every halo sync may have been unblocked).
    fn start_all(&mut self, t: f64) {
        for r in 0..self.n {
            self.try_start(r, t);
        }
    }

    /// Retry only the ranks whose `Neighbors` sync can have been newly
    /// satisfied: the halo neighbourhood of every rank in `wake` (whose
    /// `completed` just advanced), in ascending rank order — the same
    /// order, restricted to the only ranks where `try_start` is not a
    /// no-op, as the historical full `start_all` sweep.
    fn wake_neighbors(&mut self, t: f64) {
        if self.wake.is_empty() {
            return;
        }
        let radius = self.radius.min(self.n / 2);
        let mut set = std::mem::take(&mut self.wake_set);
        set.clear();
        for &r in &self.wake {
            set.push(r);
            for k in 1..=radius {
                set.push((r + self.n - k) % self.n);
                set.push((r + k) % self.n);
            }
        }
        set.sort_unstable();
        set.dedup();
        for &r in &set {
            self.try_start(r, t);
        }
        self.wake_set = set;
        self.wake.clear();
    }

    /// Complete every rank whose target the integrals have crossed in the
    /// domains listed in `due`, then retry the affected halo
    /// neighbourhoods (the batch handler of the analytic completion
    /// event). Only due domains can hold crossings: every other domain's
    /// projected completion lies strictly in the future.
    fn do_completions(&mut self, t: f64) {
        let due = std::mem::take(&mut self.due);
        for &d in &due {
            self.fold_domain(d, t);
            let lo = d * self.nk;
            for slot in lo..lo + self.nk {
                let eps = EPS_REL * (self.integral[slot].abs() + 1.0);
                loop {
                    let key = match self.groups[slot].peek() {
                        Some(k) => k.0,
                        None => break,
                    };
                    let (target, rank, ver) = entry_parts(key);
                    if ver != self.run_ver[rank] as u32 {
                        self.groups[slot].pop();
                        continue;
                    }
                    if target > self.integral[slot] + eps {
                        break;
                    }
                    self.groups[slot].pop();
                    if let RankState::Running { flat, slot: rslot, started, .. } =
                        self.states[rank]
                    {
                        self.record(rank, flat, started, t);
                        self.completed[rank] = flat as i64;
                        self.counts[rslot] -= 1;
                        self.run_ver[rank] += 1;
                        self.dirty[rslot / self.nk] = true;
                        self.states[rank] = RankState::Ready { flat: flat + 1 };
                        self.wake.push(rank);
                    }
                }
            }
        }
        self.due = due;
        self.due.clear();
        self.wake_neighbors(t);
    }

    /// Schedule the initial events of a fresh run: staggered rank starts
    /// and the first noise arrival of every enabled stream. Never called
    /// on a restored checkpoint (its queue already carries the pending
    /// events).
    fn seed(&mut self) {
        for r in 0..self.n {
            self.queue.push(r as f64 * self.stagger, EventKind::Start, r);
            if self.noise[r].enabled() {
                self.queue.push(self.noise[r].next_at(), EventKind::Noise, r);
            }
        }
    }

    /// Drive the event loop until the program finishes, `t_max` is hit
    /// (both `Finished`), or the next event would land past `t_stop`
    /// (`Paused`). The pause check observes only the *times* of the next
    /// completion and queue head — it consumes nothing — so pausing is
    /// invisible to the event sequence.
    fn run_until(&mut self, t_stop: f64) -> StepEnd {
        loop {
            let tq = self.queue.peek_time().unwrap_or(f64::INFINITY);
            let tc = self.next_complete();
            if tc.min(tq) > t_stop {
                if self.queue.is_empty() && tc == f64::INFINITY {
                    return StepEnd::Finished; // nothing pending at all
                }
                return StepEnd::Paused;
            }
            // Strict `<`: at equal times queue events fire first (completion
            // has the lowest tie-break priority, as in the legacy stepper).
            if tc < tq {
                if tc > self.t_max {
                    self.t_end = self.t_max;
                    return StepEnd::Finished;
                }
                let t = tc;
                // Every domain projecting this exact instant completes now;
                // `do_completions` sweeps exactly those, marks them dirty,
                // and `refresh` rebuilds their projections (other domains
                // keep theirs).
                for d in 0..self.nd {
                    if self.t_complete[d] == t {
                        self.t_complete[d] = f64::INFINITY;
                        self.due.push(d);
                    }
                }
                self.events += 1;
                self.t_end = t;
                self.do_completions(t);
                self.refresh(t);
                continue;
            }
            let ev = match self.queue.pop() {
                Some(e) => e,
                None => return StepEnd::Finished,
            };
            if ev.kind == EventKind::Noise {
                // Valid only while the rank runs a kernel and the arrival
                // still matches its stream (deferred arrivals are consumed
                // by `enter_running` and this entry dropped).
                let running = matches!(self.states[ev.idx], RankState::Running { .. });
                if !running || self.noise[ev.idx].next_at() != ev.t {
                    continue;
                }
            }
            if ev.t > self.t_max {
                self.t_end = self.t_max;
                return StepEnd::Finished;
            }
            self.events += 1;
            let t = ev.t;
            self.t_end = t;
            match ev.kind {
                EventKind::Start => {
                    self.states[ev.idx] = RankState::Ready { flat: 0 };
                    self.try_start(ev.idx, t);
                }
                EventKind::Noise => {
                    if let RankState::Running { flat, slot, target, started } = self.states[ev.idx]
                    {
                        self.fold_domain(slot / self.nk, t);
                        let remaining = (target - self.integral[slot]).max(0.0);
                        self.counts[slot] -= 1;
                        self.run_ver[ev.idx] += 1;
                        self.dirty[slot / self.nk] = true;
                        let dur = self.noise[ev.idx].fire(t);
                        self.states[ev.idx] = RankState::Idling {
                            flat: None,
                            until: t + dur,
                            started: t,
                            resume: Resume::Kernel { flat, slot, remaining, started },
                        };
                        self.queue.push(t + dur, EventKind::IdleEnd, ev.idx);
                        self.queue.push(self.noise[ev.idx].next_at(), EventKind::Noise, ev.idx);
                    }
                }
                EventKind::IdleEnd => {
                    if let RankState::Idling { flat, until, started, resume } = self.states[ev.idx]
                    {
                        if until <= t {
                            if let Some(fl) = flat {
                                self.record(ev.idx, fl, started, t);
                                self.completed[ev.idx] = fl as i64;
                            }
                            match resume {
                                Resume::Next { flat: next } => {
                                    self.states[ev.idx] = RankState::Ready { flat: next };
                                    self.try_start(ev.idx, t);
                                }
                                Resume::Kernel { flat: kf, slot, remaining, started } => {
                                    self.enter_running(ev.idx, kf, slot, remaining, started, t);
                                }
                            }
                            if flat.is_some() {
                                // An explicit Idle phase completed: only
                                // this rank's halo neighbours can be newly
                                // unblocked.
                                self.wake.push(ev.idx);
                                self.wake_neighbors(t);
                            }
                        }
                    }
                }
                EventKind::CollectiveRelease => {
                    let flat = ev.idx;
                    for r in 0..self.n {
                        if let RankState::Collective { flat: cf, arrived } = self.states[r] {
                            if cf == flat {
                                self.record(r, flat, arrived, t);
                                self.completed[r] = flat as i64;
                                self.states[r] = RankState::Ready { flat: flat + 1 };
                            }
                        }
                    }
                    self.start_all(t);
                }
            }
            self.refresh(t);
        }
    }

    /// Fold the sharing-model cache counters into the stats and emit the
    /// final result.
    fn finalize(self) -> CoSimResult {
        let mut stats = self.stats;
        for c in &self.share {
            let s = c.stats();
            stats.share_hits += s.hits;
            stats.share_misses += s.misses;
        }
        if let Some(r) = &self.remote {
            let (h, m, e) = r.stats();
            stats.remote_hits = h;
            stats.remote_misses = m;
            stats.remote_entries = e;
        }
        CoSimResult {
            trace: self.trace,
            finish_s: self.finish,
            t_end_s: self.t_end,
            events: self.events,
            stats,
        }
    }

    /// Move the mutable engine state out into a resumable checkpoint.
    fn checkpoint(self) -> EngineCheckpoint {
        EngineCheckpoint {
            n: self.n,
            nd: self.nd,
            nk: self.nk,
            total: self.total,
            states: self.states,
            completed: self.completed,
            trace: self.trace,
            finish: self.finish,
            noise: self.noise,
            collective_arrived: self.collective_arrived,
            queue: self.queue,
            counts: self.counts,
            integral: self.integral,
            rates: self.rates,
            t_fold: self.t_fold,
            dirty: self.dirty,
            t_complete: self.t_complete,
            run_ver: self.run_ver,
            groups: self.groups,
            events: self.events,
            stats: self.stats,
            t_end: self.t_end,
        }
    }

    /// Overwrite the freshly built (un-seeded) engine with a checkpoint's
    /// state. Dimension mismatches mean the caller resumed against a
    /// different program/layout — a contract violation, so panic.
    fn restore(&mut self, cp: EngineCheckpoint) {
        assert_eq!(self.n, cp.n, "checkpoint resumed with a different rank count");
        assert_eq!(self.nd, cp.nd, "checkpoint resumed with a different domain count");
        assert_eq!(self.nk, cp.nk, "checkpoint resumed with different kernel characterizations");
        assert_eq!(self.total, cp.total, "checkpoint resumed with a different program");
        self.states = cp.states;
        self.completed = cp.completed;
        self.trace = cp.trace;
        self.finish = cp.finish;
        self.noise = cp.noise;
        self.collective_arrived = cp.collective_arrived;
        self.queue = cp.queue;
        self.counts = cp.counts;
        self.integral = cp.integral;
        self.rates = cp.rates;
        self.t_fold = cp.t_fold;
        self.dirty = cp.dirty;
        self.t_complete = cp.t_complete;
        self.run_ver = cp.run_ver;
        self.groups = cp.groups;
        self.events = cp.events;
        self.stats = cp.stats;
        self.t_end = cp.t_end;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::desync::NoiseModel;

    fn one_kernel_program(volume: f64) -> Program {
        Program {
            phases: vec![Phase::Kernel {
                kernel: KernelId::Ddot2,
                volume_bytes: volume,
                sync: SyncKind::None,
                label: "K",
            }],
            iterations: 1,
        }
    }

    fn cfg() -> CoSimConfig {
        CoSimConfig {
            dt_s: 1.0, // must be ignored by the event engine
            t_max_s: 1e6,
            initial_stagger_s: 0.0,
            neighbor_radius: 1,
            noise: NoiseModel::off(),
        }
    }

    #[test]
    fn solo_kernel_duration_is_closed_form() {
        // One rank, one kernel: per-core rate = f * b_s (unsaturated cap).
        let (f, bs) = (0.2, 100.0);
        let volume = 3.2e9;
        let r = simulate(&one_kernel_program(volume), 1, &cfg(), &[(KernelId::Ddot2, f, bs)]);
        let expect = volume / (f * bs * 1e9);
        assert_eq!(r.trace.records.len(), 1);
        let rec = &r.trace.records[0];
        assert!((rec.duration() - expect).abs() < 1e-12 * expect, "{}", rec.duration());
        assert!((r.finish_s[0] - expect).abs() < 1e-12 * expect);
    }

    #[test]
    fn saturated_domain_shares_exactly() {
        // 10 identical ranks saturate: aggregate = b_s, per-core = b_s/10.
        let (f, bs) = (0.2, 100.0);
        let volume = 1e9;
        let r = simulate(&one_kernel_program(volume), 10, &cfg(), &[(KernelId::Ddot2, f, bs)]);
        let expect = volume / (bs / 10.0 * 1e9);
        for rec in &r.trace.records {
            assert!((rec.duration() - expect).abs() < 1e-9 * expect);
        }
        // Lockstep, no noise: everyone finishes at exactly the same instant.
        for w in r.finish_s.windows(2) {
            assert_eq!(w[0].to_bits(), w[1].to_bits());
        }
        // The share model was consulted and memoized.
        assert!(r.stats.share_misses >= 1);
    }

    #[test]
    fn idle_and_allreduce_phases_are_exact() {
        let prog = Program {
            phases: vec![
                Phase::Idle { duration_s: 0.25, label: "Wait" },
                Phase::Allreduce { cost_s: 0.5, label: "AR" },
            ],
            iterations: 1,
        };
        let r = simulate(&prog, 3, &cfg(), &[(KernelId::Ddot2, 0.2, 100.0)]);
        assert_eq!(r.trace.records.len(), 6);
        for rec in r.trace.of("Wait", None) {
            assert!((rec.duration() - 0.25).abs() < 1e-15);
        }
        for rec in r.trace.of("AR", None) {
            // All arrive at 0.25, release at 0.25 + 0.5.
            assert!((rec.t_start - 0.25).abs() < 1e-15);
            assert!((rec.t_end - 0.75).abs() < 1e-15);
        }
        for fin in &r.finish_s {
            assert!((fin - 0.75).abs() < 1e-15);
        }
    }

    #[test]
    fn noisy_run_is_bit_deterministic() {
        let mut c = cfg();
        c.noise = NoiseModel::mild(99);
        let prog = one_kernel_program(5e8);
        let a = simulate(&prog, 4, &c, &[(KernelId::Ddot2, 0.2, 100.0)]);
        let b = simulate(&prog, 4, &c, &[(KernelId::Ddot2, 0.2, 100.0)]);
        assert_eq!(a.trace.records.len(), b.trace.records.len());
        for (x, y) in a.trace.records.iter().zip(&b.trace.records) {
            assert_eq!(x.rank, y.rank);
            assert_eq!(x.label, y.label);
            assert_eq!(x.t_start.to_bits(), y.t_start.to_bits());
            assert_eq!(x.t_end.to_bits(), y.t_end.to_bits());
        }
        assert_eq!(a.events, b.events);
    }

    #[test]
    fn wall_clock_leaves_unfinished_ranks_nan() {
        let mut c = cfg();
        c.t_max_s = 1e-6; // far shorter than the kernel
        let r = simulate(&one_kernel_program(1e12), 2, &c, &[(KernelId::Ddot2, 0.2, 100.0)]);
        assert!(r.finish_s.iter().all(|f| f.is_nan()));
        assert_eq!(r.t_end_s, 1e-6);
    }

    #[test]
    fn domains_contend_independently() {
        // 8 ranks over 2 domains (4+4): each domain is a 4-core group on
        // its own memory interface, so every rank's duration equals the
        // 4-rank single-domain run — bit for bit.
        let (f, bs) = (0.4, 100.0);
        let volume = 2e9;
        let prog = one_kernel_program(volume);
        let chars = [(KernelId::Ddot2, f, bs)];
        let solo = simulate(&prog, 4, &cfg(), &chars);
        let layout = RankLayout {
            n_domains: 2,
            rank_domain: vec![0, 0, 0, 0, 1, 1, 1, 1],
            bw_scale: vec![1.0, 1.0],
            socket_of: vec![0, 0],
            node_of: vec![0, 0],
            link_bw_gbs: 0.0,
            link_bw_rev_gbs: 0.0,
            collective_extra_s: 0.0,
            remote: None,
        };
        let placed = simulate_placed(&prog, 8, &cfg(), &chars, &layout);
        assert_eq!(placed.trace.records.len(), 8);
        let want = solo.trace.records[0].duration();
        for rec in &placed.trace.records {
            assert_eq!(rec.duration().to_bits(), want.to_bits(), "rank {}", rec.rank);
        }
    }

    #[test]
    fn degenerate_layout_is_bit_identical_to_simulate() {
        let mut c = cfg();
        c.noise = NoiseModel::mild(3);
        let prog = one_kernel_program(7e8);
        let chars = [(KernelId::Ddot2, 0.3, 90.0)];
        let a = simulate(&prog, 5, &c, &chars);
        let b = simulate_placed(&prog, 5, &c, &chars, &RankLayout::single(5));
        assert_eq!(a.trace.records.len(), b.trace.records.len());
        for (x, y) in a.trace.records.iter().zip(&b.trace.records) {
            assert_eq!(x.t_start.to_bits(), y.t_start.to_bits());
            assert_eq!(x.t_end.to_bits(), y.t_end.to_bits());
        }
        assert_eq!(a.events, b.events);
    }

    #[test]
    fn scaled_domain_drains_proportionally_slower() {
        // One rank per domain, unsaturated: per-core rate is f * (s * b_s),
        // so the half-bandwidth domain takes exactly twice as long.
        let volume = 1e9;
        let prog = one_kernel_program(volume);
        let chars = [(KernelId::Ddot2, 0.2, 100.0)];
        let layout = RankLayout {
            n_domains: 2,
            rank_domain: vec![0, 1],
            bw_scale: vec![1.0, 0.5],
            socket_of: vec![0, 0],
            node_of: vec![0, 0],
            link_bw_gbs: 0.0,
            link_bw_rev_gbs: 0.0,
            collective_extra_s: 0.0,
            remote: None,
        };
        let r = simulate_placed(&prog, 2, &cfg(), &chars, &layout);
        let d0 = r.trace.records.iter().find(|x| x.rank == 0).unwrap().duration();
        let d1 = r.trace.records.iter().find(|x| x.rank == 1).unwrap().duration();
        assert!((d1 - 2.0 * d0).abs() < 1e-9 * d1, "{d1} vs 2x{d0}");
    }

    #[test]
    fn all_zero_remote_spec_is_bit_identical_to_none() {
        use crate::topology::RemoteTraffic;
        let prog = one_kernel_program(2e9);
        let chars = [(KernelId::Ddot2, 0.4, 100.0)];
        let base = RankLayout {
            n_domains: 2,
            rank_domain: vec![0, 0, 1, 1],
            bw_scale: vec![1.0, 1.0],
            socket_of: vec![0, 1],
            node_of: vec![0, 0],
            link_bw_gbs: 40.0,
            link_bw_rev_gbs: 40.0,
            collective_extra_s: 0.0,
            remote: None,
        };
        let mut zeroed = base.clone();
        zeroed.remote = Some(RemoteTraffic { frac: vec![0.0, 0.0] });
        let a = simulate_placed(&prog, 4, &cfg(), &chars, &base);
        let b = simulate_placed(&prog, 4, &cfg(), &chars, &zeroed);
        assert_eq!(a.trace.records.len(), b.trace.records.len());
        for (x, y) in a.trace.records.iter().zip(&b.trace.records) {
            assert_eq!(x.t_start.to_bits(), y.t_start.to_bits());
            assert_eq!(x.t_end.to_bits(), y.t_end.to_bits());
        }
        assert_eq!(a.events, b.events);
    }

    #[test]
    fn symmetric_intra_socket_remote_is_neutral() {
        // Both domains run the same composition and exchange equal traffic
        // with no link in the way: every domain receives exactly what it
        // exports, so the drain rates match the all-local run.
        let prog = one_kernel_program(1.5e9);
        let chars = [(KernelId::Ddot2, 0.4, 100.0)];
        let mk = |remote: Option<f64>| {
            let layout = RankLayout {
                n_domains: 2,
                rank_domain: vec![0, 0, 0, 1, 1, 1],
                bw_scale: vec![1.0, 1.0],
                socket_of: vec![0, 0],
                node_of: vec![0, 0],
                link_bw_gbs: 0.0,
                link_bw_rev_gbs: 0.0,
                collective_extra_s: 0.0,
                remote: None,
            };
            let layout = match remote {
                Some(f) => layout.with_remote(f).unwrap(),
                None => layout,
            };
            simulate_placed(&prog, 6, &cfg(), &chars, &layout)
        };
        let local = mk(None);
        let spread = mk(Some(0.5));
        for (x, y) in local.trace.records.iter().zip(&spread.trace.records) {
            let (a, b) = (x.duration(), y.duration());
            assert!((a - b).abs() < 1e-9 * a, "rank {}: {a} vs {b}", x.rank);
        }
    }

    #[test]
    fn saturated_link_slows_cross_socket_remote_drain() {
        let prog = one_kernel_program(1.5e9);
        let chars = [(KernelId::Ddot2, 0.4, 100.0)];
        let mk = |link_bw: f64, frac: f64| {
            let layout = RankLayout {
                n_domains: 2,
                rank_domain: vec![0, 0, 0, 1, 1, 1],
                bw_scale: vec![1.0, 1.0],
                socket_of: vec![0, 1],
                node_of: vec![0, 0],
                link_bw_gbs: link_bw,
                link_bw_rev_gbs: link_bw,
                collective_extra_s: 0.0,
                remote: None,
            }
            .with_remote(frac)
            .unwrap();
            simulate_placed(&prog, 6, &cfg(), &chars, &layout)
        };
        let wide = mk(1000.0, 0.5);
        let narrow = mk(2.0, 0.5);
        let (a, b) = (wide.trace.records[0].duration(), narrow.trace.records[0].duration());
        assert!(b > 1.5 * a, "narrow-link duration {b} should far exceed {a}");
    }

    #[test]
    fn collective_extra_delays_every_release() {
        let prog = Program {
            phases: vec![Phase::Allreduce { cost_s: 0.5, label: "AR" }],
            iterations: 1,
        };
        let mut layout = RankLayout::single(3);
        layout.collective_extra_s = 1e-3;
        let r = simulate_placed(&prog, 3, &cfg(), &[(KernelId::Ddot2, 0.2, 100.0)], &layout);
        for fin in &r.finish_s {
            assert!((fin - 0.501).abs() < 1e-12, "finish {fin}");
        }
    }

    #[test]
    fn two_groups_drain_at_model_rates() {
        // 3 ddot2 cores + 2 daxpy cores, saturated: per-core rates follow
        // the generalized Eq. 5 split exactly.
        use crate::sharing::{share_multigroup, KernelGroup};
        let chars = [(KernelId::Ddot2, 0.4, 100.0), (KernelId::Daxpy, 0.6, 90.0)];
        let vol = 1e9;
        let prog = Program {
            phases: vec![
                Phase::Kernel {
                    kernel: KernelId::Ddot2,
                    volume_bytes: vol,
                    sync: SyncKind::None,
                    label: "A",
                },
                Phase::Kernel {
                    kernel: KernelId::Daxpy,
                    volume_bytes: vol,
                    sync: SyncKind::None,
                    label: "B",
                },
            ],
            iterations: 1,
        };
        // Every rank runs A then B in lockstep, so phase 1 is a single
        // 5-core ddot2 group whose duration has a closed form.
        let n = 5;
        let r = simulate(&prog, n, &cfg(), &chars);
        let share_a = share_multigroup(&[KernelGroup { n, f: 0.4, bs_gbs: 100.0 }]);
        let expect_a = vol / (share_a.groups[0].per_core_gbs * 1e9);
        for rec in r.trace.of("A", None) {
            assert!(
                (rec.duration() - expect_a).abs() < 1e-9 * expect_a,
                "A duration {} vs {}",
                rec.duration(),
                expect_a
            );
        }
    }

    /// A hand-built 2-node cluster: each node is one socket with two
    /// ccNUMA domains exchanging intra-node remote traffic.
    fn two_node_layout(frac: f64) -> RankLayout {
        RankLayout {
            n_domains: 4,
            rank_domain: vec![0, 0, 1, 1, 2, 2, 3, 3],
            bw_scale: vec![1.0; 4],
            socket_of: vec![0, 0, 1, 1],
            node_of: vec![0, 0, 1, 1],
            link_bw_gbs: 0.0,
            link_bw_rev_gbs: 0.0,
            collective_extra_s: 0.0,
            remote: None,
        }
        .with_remote(frac)
        .unwrap()
    }

    #[test]
    fn cluster_nodes_contend_independently_under_remote() {
        // Remote traffic never leaves a node: each node of the 2-node
        // cluster reproduces the single-node 4-rank run bit for bit.
        let prog = one_kernel_program(1.5e9);
        let chars = [(KernelId::Ddot2, 0.4, 100.0)];
        let solo_layout = RankLayout {
            n_domains: 2,
            rank_domain: vec![0, 0, 1, 1],
            bw_scale: vec![1.0, 1.0],
            socket_of: vec![0, 0],
            node_of: vec![0, 0],
            link_bw_gbs: 0.0,
            link_bw_rev_gbs: 0.0,
            collective_extra_s: 0.0,
            remote: None,
        }
        .with_remote(0.5)
        .unwrap();
        let solo = simulate_placed(&prog, 4, &cfg(), &chars, &solo_layout);
        let cluster = simulate_placed(&prog, 8, &cfg(), &chars, &two_node_layout(0.5));
        assert_eq!(cluster.trace.records.len(), 8);
        let want = solo.trace.records[0].duration();
        for rec in &cluster.trace.records {
            assert_eq!(rec.duration().to_bits(), want.to_bits(), "rank {}", rec.rank);
        }
    }

    #[test]
    fn incremental_rating_is_bit_identical_to_full_recompute() {
        // Noise desynchronizes the two nodes, so the incremental path
        // skips clean-node re-ratings — without changing a single bit of
        // the trace (rates are pure functions of the node composition).
        let mut c = cfg();
        c.noise = NoiseModel::mild(11);
        c.initial_stagger_s = 1e-4;
        let prog = one_kernel_program(9e8);
        let chars = [(KernelId::Ddot2, 0.4, 100.0)];
        let layout = two_node_layout(0.4);
        let incr = simulate_placed_mode(&prog, 8, &c, &chars, &layout, RatingMode::Incremental);
        let full = simulate_placed_mode(&prog, 8, &c, &chars, &layout, RatingMode::FullRecompute);
        assert_eq!(incr.trace.records.len(), full.trace.records.len());
        for (x, y) in incr.trace.records.iter().zip(&full.trace.records) {
            assert_eq!(x.rank, y.rank);
            assert_eq!(x.t_start.to_bits(), y.t_start.to_bits());
            assert_eq!(x.t_end.to_bits(), y.t_end.to_bits());
        }
        assert_eq!(incr.events, full.events);
        for (a, b) in incr.finish_s.iter().zip(&full.finish_s) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
        // The all-dirty fallback is gone: the incremental path skipped
        // clean nodes, the reference rated every node on every refresh.
        assert!(incr.stats.node_rates_reused > 0, "no clean-node skips recorded");
        assert!(
            incr.stats.rate_evals < full.stats.rate_evals,
            "incremental ({}) must rate fewer nodes than full ({})",
            incr.stats.rate_evals,
            full.stats.rate_evals
        );
        assert_eq!(full.stats.node_rates_reused, 0);
        assert!(incr.stats.remote_misses > 0);
    }

    #[test]
    fn paused_and_resumed_run_is_bit_identical() {
        // Drive the same noisy cluster run in 1 ms slices through the
        // checkpoint API and compare against the uninterrupted run, bit
        // for bit (stats excluded: the rebuilt share/remote memos count
        // only the final segment).
        let mut c = cfg();
        c.noise = NoiseModel::mild(7);
        c.initial_stagger_s = 1e-4;
        let prog = one_kernel_program(9e8);
        let chars = [(KernelId::Ddot2, 0.4, 100.0)];
        let layout = two_node_layout(0.4);
        let oneshot = simulate_placed(&prog, 8, &c, &chars, &layout);
        let mut t_stop = 1e-3;
        let mut step =
            simulate_placed_until(&prog, 8, &c, &chars, &layout, RatingMode::Incremental, t_stop);
        let mut resumes = 0;
        let sliced = loop {
            match step {
                SimStep::Done(r) => break r,
                SimStep::Paused(cp) => {
                    assert!(cp.t_end() <= t_stop);
                    t_stop += 1e-3;
                    resumes += 1;
                    step = resume_placed(
                        &prog,
                        8,
                        &c,
                        &chars,
                        &layout,
                        RatingMode::Incremental,
                        cp,
                        t_stop,
                    );
                }
            }
        };
        assert!(resumes > 3, "test slices too coarse to exercise resume ({resumes})");
        assert_eq!(oneshot.trace.records.len(), sliced.trace.records.len());
        for (x, y) in oneshot.trace.records.iter().zip(&sliced.trace.records) {
            assert_eq!(x.rank, y.rank);
            assert_eq!(x.label, y.label);
            assert_eq!(x.t_start.to_bits(), y.t_start.to_bits());
            assert_eq!(x.t_end.to_bits(), y.t_end.to_bits());
        }
        for (a, b) in oneshot.finish_s.iter().zip(&sliced.finish_s) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
        assert_eq!(oneshot.t_end_s.to_bits(), sliced.t_end_s.to_bits());
        assert_eq!(oneshot.events, sliced.events);
    }

    #[test]
    fn idle_nodes_are_never_re_rated() {
        // Ranks only on node 0: node 1 never gets dirty, so the
        // incremental path evaluates exactly one node per refresh (the
        // historical fallback re-rated the whole shape every time).
        let prog = one_kernel_program(1e9);
        let chars = [(KernelId::Ddot2, 0.4, 100.0)];
        let mut layout = two_node_layout(0.5);
        layout.rank_domain = vec![0, 0, 1, 1];
        let r = simulate_placed(&prog, 4, &cfg(), &chars, &layout);
        assert!(r.finish_s.iter().all(|f| f.is_finite()));
        assert!(r.stats.node_rates_reused >= r.stats.rate_evals);
    }

    #[test]
    #[should_panic(expected = "cluster nodes must share one bandwidth profile")]
    fn non_uniform_cluster_nodes_are_rejected() {
        let prog = one_kernel_program(1e9);
        let chars = [(KernelId::Ddot2, 0.4, 100.0)];
        let mut layout = two_node_layout(0.5);
        layout.bw_scale = vec![1.0, 1.0, 1.0, 0.5];
        simulate_placed(&prog, 8, &cfg(), &chars, &layout);
    }
}
