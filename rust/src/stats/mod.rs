//! Statistics utilities: descriptive stats (including the dimensioned
//! skewness the paper reports in milliseconds), relative-error metrics and
//! box-plot summaries for Fig. 8.

mod boxplot;
mod descriptive;
mod error_metrics;

pub use boxplot::BoxSummary;
pub use descriptive::{mean, median, skewness_dimensioned, skewness_standard, std_dev, Summary};
pub use error_metrics::{max_rel_error, rel_error, ErrorStats};
