//! Scenario specifications: arbitrary k-group workload mixes and
//! time-phased sequences of mixes.
//!
//! A [`Mix`] generalizes [`crate::sweep::PairingCase`] from two thread
//! groups to any number of groups plus explicit idle cores (scenario (c) of
//! the paper's Fig. 2 — idle/communicating cores are simply absent from the
//! contention). A [`Scenario`] is a named sequence of mixes, modelling a
//! program that moves through workload phases (the desynchronization
//! phenomenology of Figs. 1–3: at any instant cores are spread over several
//! kernels and idle waits).
//!
//! Mixes have a compact text form for the CLI:
//! `"dcopy:4+ddot2:4+idle:2"`; scenarios join phases with `/`:
//! `"dcopy:8+ddot2:8 / dcopy:4+idle:12"`. On a multi-domain
//! [`crate::topology::Topology`] each group takes an optional placement
//! suffix — `"dcopy:12@scatter"` spreads a group over the domains,
//! `"ddot2:4@d0+dcopy:4@d1"` pins groups to specific ccNUMA domains — and
//! an optional remote-access fraction: `"dcopy:8@d0%r0.25"` keeps the
//! group's cores on domain 0 but sends a quarter of its cache-line stream
//! to the other domains (crossing the inter-socket links where the target
//! lives on another socket). Parse errors are structured
//! ([`Error::MixParse`]: byte position plus the expected token).

use crate::config::Machine;
use crate::error::{Error, Result};
use crate::kernels::KernelId;
use crate::sweep::PairingCase;
use crate::topology::{GroupPlacement, Placement, Topology};

/// Reduce a user-supplied name to a safe file stem: `[A-Za-z0-9._-]` kept,
/// everything else (path separators, spaces, ...) mapped to `-`.
pub fn slugify(name: &str) -> String {
    let slug: String = name
        .chars()
        .map(|c| if c.is_ascii_alphanumeric() || matches!(c, '.' | '_' | '-') { c } else { '-' })
        .collect();
    let trimmed = slug.trim_matches(|c| c == '.' || c == '-').to_string();
    if trimmed.is_empty() {
        "scenario".to_string()
    } else {
        trimmed
    }
}

/// Boundedness override for a group (`@mem`/`@l3`/`@comp` DSL suffixes).
///
/// `Auto` (the default, no suffix) classifies from the kernel signature:
/// a group is L3-resident when its working set produces no memory traffic
/// but does move L2↔L3 lines (and the machine models `l3_bw_gbs`), and
/// compute-bound when its roofline knee lies beyond the machine's core
/// count (`f · cores < 1` — memory can never saturate, so every core runs
/// at its core-bound rate). The explicit suffixes force the classification —
/// e.g. `@l3` for a blocked/tiled kernel the static signature cannot see.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum BoundHint {
    /// Classify from the kernel signature (no suffix).
    Auto,
    /// Force memory-bound: contend on the home memory controller.
    Mem,
    /// Force L3-resident: contend on the home socket's shared-L3
    /// interface (needs `l3_bw_gbs > 0` on the machine).
    L3,
    /// Force compute-bound: cap at the core-bound rate, zero bandwidth
    /// share.
    Compute,
}

impl Default for BoundHint {
    fn default() -> Self {
        BoundHint::Auto
    }
}

impl BoundHint {
    /// Canonical DSL suffix (empty for `Auto`).
    pub fn suffix(&self) -> &'static str {
        match self {
            BoundHint::Auto => "",
            BoundHint::Mem => "@mem",
            BoundHint::L3 => "@l3",
            BoundHint::Compute => "@comp",
        }
    }
}

/// Parse a bound-override suffix token (without the `@`).
fn parse_bound_hint(s: &str) -> Option<BoundHint> {
    match s.to_ascii_lowercase().as_str() {
        "mem" => Some(BoundHint::Mem),
        "l3" => Some(BoundHint::L3),
        "comp" | "compute" => Some(BoundHint::Compute),
        _ => None,
    }
}

/// One group of cores all executing the same kernel.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct GroupSpec {
    /// Kernel the group runs.
    pub kernel: KernelId,
    /// Number of cores in the group.
    pub cores: usize,
    /// Where the group goes on a multi-domain topology (`Auto` = follow
    /// the mix-level placement policy; irrelevant on a single domain).
    pub place: GroupPlacement,
    /// Remote-access fraction in parts per million: how much of the
    /// group's cache-line stream targets remote ccNUMA domains (`%r`
    /// suffix in the DSL; 0 = all traffic stays home). Stored as an
    /// integer so mixes stay `Eq`/hashable; use
    /// [`GroupSpec::remote_frac`] for the `f64` value.
    pub remote_ppm: u32,
    /// Boundedness override (`@mem`/`@l3`/`@comp` suffix; `Auto` = none).
    pub bound: BoundHint,
}

impl GroupSpec {
    /// The remote-access fraction as a float in `[0, 1]`.
    pub fn remote_frac(&self) -> f64 {
        self.remote_ppm as f64 / 1e6
    }
}

/// Convert a remote fraction in `[0, 1]` to the parts-per-million fixed
/// point [`GroupSpec::remote_ppm`] stores.
pub fn remote_ppm_of(frac: f64) -> u32 {
    debug_assert!(frac.is_finite() && (0.0..=1.0).contains(&frac));
    (frac * 1e6).round() as u32
}

/// An instantaneous workload mix: k kernel groups plus idle cores.
#[derive(Debug, Clone, Default, PartialEq, Eq)]
pub struct Mix {
    /// Kernel groups, in core order (group i occupies the cores after
    /// groups 0..i).
    pub groups: Vec<GroupSpec>,
    /// Cores that issue no memory traffic (idle or communicating).
    pub idle_cores: usize,
}

impl Mix {
    /// Start an empty mix (builder entry point).
    pub fn new() -> Self {
        Mix::default()
    }

    /// Add a kernel group of `cores` cores (default placement).
    pub fn with(self, kernel: KernelId, cores: usize) -> Self {
        self.with_on(kernel, cores, GroupPlacement::Auto)
    }

    /// Add a kernel group with an explicit topology placement.
    pub fn with_on(mut self, kernel: KernelId, cores: usize, place: GroupPlacement) -> Self {
        self.groups.push(GroupSpec {
            kernel,
            cores,
            place,
            remote_ppm: 0,
            bound: BoundHint::Auto,
        });
        self
    }

    /// Add a kernel group with a placement and an explicit boundedness
    /// override (the `@l3`/`@comp`/`@mem` DSL suffixes as a builder).
    pub fn with_bound_on(
        mut self,
        kernel: KernelId,
        cores: usize,
        place: GroupPlacement,
        bound: BoundHint,
    ) -> Self {
        self.groups.push(GroupSpec { kernel, cores, place, remote_ppm: 0, bound });
        self
    }

    /// Add a kernel group with a placement and a remote-access fraction
    /// (the `%r` DSL suffix as a builder).
    ///
    /// # Panics
    /// If `remote_frac` is outside `[0, 1]` (a programming error; the DSL
    /// parser reports the same condition as a structured
    /// [`Error::MixParse`]).
    pub fn with_remote_on(
        mut self,
        kernel: KernelId,
        cores: usize,
        place: GroupPlacement,
        remote_frac: f64,
    ) -> Self {
        assert!(
            remote_frac.is_finite() && (0.0..=1.0).contains(&remote_frac),
            "remote fraction {remote_frac} outside [0, 1]"
        );
        let remote_ppm = remote_ppm_of(remote_frac);
        self.groups.push(GroupSpec { kernel, cores, place, remote_ppm, bound: BoundHint::Auto });
        self
    }

    /// Whether any group sends traffic to remote domains.
    pub fn has_remote(&self) -> bool {
        self.groups.iter().any(|g| g.remote_ppm > 0)
    }

    /// Apply `remote_frac` to every group that has no explicit `%r` suffix
    /// (the CLI's `--remote-frac` default).
    ///
    /// # Panics
    /// If `remote_frac` is outside `[0, 1]`.
    pub fn with_default_remote(mut self, remote_frac: f64) -> Self {
        assert!(
            remote_frac.is_finite() && (0.0..=1.0).contains(&remote_frac),
            "remote fraction {remote_frac} outside [0, 1]"
        );
        let ppm = remote_ppm_of(remote_frac);
        for g in &mut self.groups {
            if g.remote_ppm == 0 {
                g.remote_ppm = ppm;
            }
        }
        self
    }

    /// Add `cores` idle cores.
    pub fn idle(mut self, cores: usize) -> Self {
        self.idle_cores += cores;
        self
    }

    /// The k=2 special case: a pairing case as a mix.
    pub fn from_pairing(case: &PairingCase) -> Self {
        Mix::new().with(case.k1, case.n1).with(case.k2, case.n2)
    }

    /// Number of kernel groups (k).
    pub fn k(&self) -> usize {
        self.groups.len()
    }

    /// Cores executing kernels.
    pub fn active_cores(&self) -> usize {
        self.groups.iter().map(|g| g.cores).sum()
    }

    /// Active plus idle cores.
    pub fn total_cores(&self) -> usize {
        self.active_cores() + self.idle_cores
    }

    /// Distinct kernels appearing in the mix.
    pub fn kernels(&self) -> Vec<KernelId> {
        let mut ks: Vec<KernelId> = self.groups.iter().map(|g| g.kernel).collect();
        ks.sort_by_key(|k| k.key());
        ks.dedup();
        ks
    }

    /// Check the bound-override constraints against a machine's shared-L3
    /// capacity: `@l3` groups need a modeled L3 and cannot also send
    /// remote traffic (an L3-resident working set does not cross sockets).
    pub fn validate_bounds(&self, l3_bw_gbs: f64) -> Result<()> {
        for g in &self.groups {
            if g.bound == BoundHint::L3 {
                if l3_bw_gbs <= 0.0 {
                    return Err(Error::InvalidPlan(format!(
                        "mix '{}': group '{}' is forced @l3 but the machine models no \
                         shared-L3 bandwidth (l3_bw_gbs = 0)",
                        self.label(),
                        g.kernel.key()
                    )));
                }
                if g.remote_ppm > 0 {
                    return Err(Error::InvalidPlan(format!(
                        "mix '{}': group '{}' is forced @l3 and cannot also carry a \
                         remote-access fraction",
                        self.label(),
                        g.kernel.key()
                    )));
                }
            }
        }
        Ok(())
    }

    /// Validate the mix against a machine's contention domain.
    pub fn validate(&self, m: &Machine) -> Result<()> {
        if self.active_cores() == 0 {
            return Err(Error::InvalidPlan(format!(
                "mix '{}' has no active cores",
                self.label()
            )));
        }
        self.validate_bounds(m.l3_bw_gbs)?;
        if self.has_remote() {
            return Err(Error::InvalidPlan(format!(
                "mix '{}' carries remote-access fractions, which need a multi-domain topology",
                self.label()
            )));
        }
        if self.total_cores() > m.cores {
            return Err(Error::InvalidPlan(format!(
                "mix '{}' needs {} cores but the {} domain has {}",
                self.label(),
                self.total_cores(),
                m.name,
                m.cores
            )));
        }
        Ok(())
    }

    /// Canonical text form: `kernel:cores[@place][@bound][%rF]` joined by
    /// `+`, idle last.
    pub fn label(&self) -> String {
        let mut parts: Vec<String> = self
            .groups
            .iter()
            .map(|g| {
                let remote = if g.remote_ppm > 0 {
                    format!("%r{}", g.remote_frac())
                } else {
                    String::new()
                };
                format!(
                    "{}:{}{}{}{}",
                    g.kernel.key(),
                    g.cores,
                    g.place.suffix(),
                    g.bound.suffix(),
                    remote
                )
            })
            .collect();
        if self.idle_cores > 0 {
            parts.push(format!("idle:{}", self.idle_cores));
        }
        parts.join("+")
    }

    /// Parse the text form (`"dcopy:4+ddot2:4+idle:2"`; optional
    /// `@dN`/`@scatter`/`@compact` placement suffix and `%rF` remote
    /// fraction per group, in that order — `"dcopy:8@d0%r0.25"`;
    /// whitespace around `+` is tolerated). Inverse of [`Mix::label`].
    /// Errors are structured ([`Error::MixParse`]): byte position of the
    /// offending token plus the token class the parser expected there.
    pub fn parse(s: &str) -> Result<Self> {
        Mix::parse_at(s, s, 0)
    }

    /// [`Mix::parse`] on a slice of a larger spec: `full` is the complete
    /// spec string (error context), `base` the byte offset of `s` in it.
    pub(crate) fn parse_at(s: &str, full: &str, base: usize) -> Result<Self> {
        let err = |pos: usize, expected: &str, found: &str| Error::MixParse {
            spec: full.to_string(),
            pos,
            expected: expected.to_string(),
            found: found.to_string(),
        };
        let mut mix = Mix::new();
        let mut off = 0usize;
        for part in s.split('+') {
            // Byte offset of the trimmed term within `full`.
            let tstart = base + off + (part.len() - part.trim_start().len());
            off += part.len() + 1;
            let term = part.trim();
            if term.is_empty() {
                continue;
            }
            let (name_raw, rest) = match term.split_once(':') {
                Some(x) => x,
                None => return Err(err(tstart, "'kernel:cores' term", term)),
            };
            let (body_raw, remote_raw) = match rest.split_once('%') {
                Some((b, r)) => (b, Some(r)),
                None => (rest, None),
            };
            let (count_raw, place_raw) = match body_raw.split_once('@') {
                Some((c, p)) => (c, Some(p)),
                None => (body_raw, None),
            };
            let count_pos =
                tstart + name_raw.len() + 1 + (count_raw.len() - count_raw.trim_start().len());
            let count_txt = count_raw.trim();
            let cores: usize = count_txt
                .parse()
                .map_err(|_| err(count_pos, "core count", count_txt))?;
            if cores == 0 {
                return Err(err(count_pos, "positive core count", "0"));
            }
            // The `@` suffix chain: at most one placement and at most one
            // bound override, in either order (`dcopy:4@d0@l3`,
            // `fma:4@comp@scatter`). `@compact` is a placement, `@comp` a
            // bound — exact spellings disambiguate.
            let mut place = GroupPlacement::Auto;
            let mut bound = BoundHint::Auto;
            if let Some(chain) = place_raw {
                let mut spos = tstart + name_raw.len() + 1 + count_raw.len() + 1;
                for tok in chain.split('@') {
                    let tpos = spos + (tok.len() - tok.trim_start().len());
                    spos += tok.len() + 1;
                    let t = tok.trim();
                    if let Some(b) = parse_bound_hint(t) {
                        if bound != BoundHint::Auto {
                            return Err(err(tpos, "at most one bound override per group", t));
                        }
                        bound = b;
                    } else if let Some(p) = parse_group_placement(t) {
                        if place != GroupPlacement::Auto {
                            return Err(err(tpos, "at most one placement per group", t));
                        }
                        place = p;
                    } else {
                        return Err(err(
                            tpos,
                            "placement 'dN', 'scatter' or 'compact', \
                             or bound 'mem', 'l3' or 'comp'",
                            t,
                        ));
                    }
                }
            }
            let remote_ppm = match remote_raw {
                None => 0,
                Some(r) => {
                    let rpos = tstart
                        + name_raw.len()
                        + 1
                        + body_raw.len()
                        + 1
                        + (r.len() - r.trim_start().len());
                    let rtxt = r.trim();
                    let frac = rtxt
                        .strip_prefix('r')
                        .and_then(|v| v.trim().parse::<f64>().ok())
                        .filter(|v| v.is_finite() && (0.0..=1.0).contains(v));
                    match frac {
                        Some(v) => remote_ppm_of(v),
                        None => {
                            return Err(err(rpos, "remote fraction 'rF' with F in [0, 1]", rtxt))
                        }
                    }
                }
            };
            let name = name_raw.trim();
            if name.eq_ignore_ascii_case("idle") {
                if place != GroupPlacement::Auto {
                    return Err(err(
                        tstart,
                        "no placement suffix on idle cores (they do not contend)",
                        term,
                    ));
                }
                if bound != BoundHint::Auto {
                    return Err(err(
                        tstart,
                        "no bound override on idle cores (they do not contend)",
                        term,
                    ));
                }
                if remote_ppm > 0 {
                    return Err(err(
                        tstart,
                        "no remote fraction on idle cores (they issue no traffic)",
                        term,
                    ));
                }
                mix = mix.idle(cores);
            } else {
                let kernel = KernelId::parse(name)
                    .map_err(|_| err(tstart, "kernel name or 'idle'", name))?;
                mix = mix.with_on(kernel, cores, place);
                let g = mix.groups.last_mut().expect("group just pushed");
                g.remote_ppm = remote_ppm;
                g.bound = bound;
            }
        }
        if mix.groups.is_empty() && mix.idle_cores == 0 {
            return Err(err(base, "at least one 'kernel:cores' term", s.trim()));
        }
        Ok(mix)
    }

    /// Validate the mix against a topology under a placement policy:
    /// active cores present, every `@dN` pin in range, every group and the
    /// idle cores placeable (all checked by [`Placement::split`]), and the
    /// bound-override constraints against the base machine.
    pub fn validate_on(&self, topo: &Topology, placement: Placement) -> Result<()> {
        self.validate_bounds(topo.base.l3_bw_gbs)?;
        placement.split(topo, self).map(|_| ())
    }
}

/// Parse a group-placement suffix (without the `@`).
fn parse_group_placement(s: &str) -> Option<GroupPlacement> {
    let t = s.to_ascii_lowercase();
    match t.as_str() {
        "scatter" => Some(GroupPlacement::Scatter),
        "compact" => Some(GroupPlacement::Compact),
        _ => t
            .strip_prefix('d')
            .and_then(|n| n.parse::<usize>().ok())
            .map(GroupPlacement::Domain),
    }
}

/// A named, time-phased sequence of mixes. Each phase is measured at its own
/// steady state (the engines simulate stationary contention, matching the
/// sharing model's per-composition evaluation in the desync co-simulator).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Scenario {
    /// Display name (also used for result file names).
    pub name: String,
    /// Phases, in time order.
    pub mixes: Vec<Mix>,
}

impl Scenario {
    /// Start an empty scenario.
    pub fn new(name: &str) -> Self {
        Scenario { name: name.to_string(), mixes: Vec::new() }
    }

    /// Append a phase.
    pub fn then(mut self, mix: Mix) -> Self {
        self.mixes.push(mix);
        self
    }

    /// Parse a `/`-separated sequence of mix specs. Parse errors carry byte
    /// positions relative to the full scenario spec.
    pub fn parse(name: &str, s: &str) -> Result<Self> {
        let mut mixes = Vec::new();
        let mut off = 0usize;
        for part in s.split('/') {
            let start = off;
            off += part.len() + 1;
            if part.trim().is_empty() {
                continue;
            }
            mixes.push(Mix::parse_at(part, s, start)?);
        }
        if mixes.is_empty() {
            return Err(Error::InvalidPlan(format!("empty scenario spec '{s}'")));
        }
        Ok(Scenario { name: name.to_string(), mixes })
    }

    /// Validate every phase against a machine.
    pub fn validate(&self, m: &Machine) -> Result<()> {
        for mix in &self.mixes {
            mix.validate(m)?;
        }
        Ok(())
    }

    /// Validate every phase against a topology under a placement policy.
    pub fn validate_on(&self, topo: &Topology, placement: Placement) -> Result<()> {
        for mix in &self.mixes {
            mix.validate_on(topo, placement)?;
        }
        Ok(())
    }

    /// Whether any phase sends traffic to remote domains.
    pub fn has_remote(&self) -> bool {
        self.mixes.iter().any(|m| m.has_remote())
    }

    /// Apply `remote_frac` to every group of every phase that has no
    /// explicit `%r` suffix (the CLI's `--remote-frac` default). See
    /// [`Mix::with_default_remote`].
    pub fn with_default_remote(mut self, remote_frac: f64) -> Self {
        self.mixes = self
            .mixes
            .into_iter()
            .map(|m| m.with_default_remote(remote_frac))
            .collect();
        self
    }

    /// Safe file stem derived from the scenario name (see [`slugify`]).
    pub fn file_stem(&self) -> String {
        slugify(&self.name)
    }

    /// A built-in demo scenario scaled to a machine: a fully populated
    /// 3-group phase, a partially idle phase, and a 4-group phase.
    pub fn demo(m: &Machine) -> Self {
        let c = m.cores;
        let third = c / 3;
        let quarter = c / 4;
        Scenario::new("demo")
            .then(
                Mix::new()
                    .with(KernelId::Dcopy, third)
                    .with(KernelId::Ddot2, third)
                    .with(KernelId::Stream, c - 2 * third),
            )
            .then(
                Mix::new()
                    .with(KernelId::Dcopy, third)
                    .with(KernelId::Ddot2, third)
                    .idle(c - 2 * third),
            )
            .then(
                Mix::new()
                    .with(KernelId::VecSum, quarter.max(1))
                    .with(KernelId::Daxpy, quarter.max(1))
                    .with(KernelId::Schoenauer, quarter.max(1))
                    .with(KernelId::Dscal, c.saturating_sub(3 * quarter.max(1)).clamp(1, c)),
            )
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{machine, MachineId};

    #[test]
    fn builder_and_label_roundtrip() {
        let mix = Mix::new()
            .with(KernelId::Dcopy, 4)
            .with(KernelId::Ddot2, 4)
            .idle(2);
        assert_eq!(mix.k(), 2);
        assert_eq!(mix.active_cores(), 8);
        assert_eq!(mix.total_cores(), 10);
        assert_eq!(mix.label(), "dcopy:4+ddot2:4+idle:2");
        let back = Mix::parse(&mix.label()).unwrap();
        assert_eq!(back, mix);
    }

    #[test]
    fn parse_tolerates_whitespace_and_aliases() {
        let mix = Mix::parse(" triad:3 + IDLE:2 + ddot2:1 ").unwrap();
        assert_eq!(mix.groups[0].kernel, KernelId::Stream);
        assert_eq!(mix.idle_cores, 2);
        assert_eq!(mix.active_cores(), 4);
    }

    #[test]
    fn parse_rejects_malformed_specs() {
        assert!(Mix::parse("dcopy").is_err());
        assert!(Mix::parse("dcopy:x").is_err());
        assert!(Mix::parse("nosuchkernel:2").is_err());
        assert!(Mix::parse("").is_err());
        assert!(Mix::parse("dcopy:0+ddot2:4").is_err(), "zero-core groups are rejected");
        assert!(Mix::parse("idle:0").is_err());
    }

    /// Parse errors are structured: byte position + expected token.
    #[test]
    fn parse_errors_carry_position_and_expectation() {
        let case = |spec: &str, want_pos: usize, want_expected: &str| {
            match Mix::parse(spec).unwrap_err() {
                Error::MixParse { spec: s, pos, expected, .. } => {
                    assert_eq!(s, spec, "spec echoed");
                    assert_eq!(pos, want_pos, "position in '{spec}'");
                    assert!(
                        expected.contains(want_expected),
                        "'{spec}': expected token '{expected}' should mention '{want_expected}'"
                    );
                }
                other => panic!("'{spec}': wanted MixParse, got {other}"),
            }
        };
        case("dcopy:", 6, "core count");
        case("x:4@d9", 0, "kernel name");
        case("dcopy:4+ddot2:y", 14, "core count");
        case("dcopy:4+ddot2", 8, "'kernel:cores'");
        case("dcopy:0", 6, "positive core count");
        case("dcopy:4@nowhere", 8, "placement");
        case("idle:2@d1", 0, "idle");
        // Positions are relative to the full scenario spec.
        match Scenario::parse("t", "dcopy:4 / ddot2:").unwrap_err() {
            Error::MixParse { pos, expected, .. } => {
                assert_eq!(pos, 16);
                assert!(expected.contains("core count"));
            }
            other => panic!("wanted MixParse, got {other}"),
        }
    }

    #[test]
    fn placement_suffixes_roundtrip() {
        let mix = Mix::parse("ddot2:4@d0+dcopy:4@d1+stream:12@scatter+daxpy:4@compact+idle:2")
            .unwrap();
        assert_eq!(mix.groups[0].place, GroupPlacement::Domain(0));
        assert_eq!(mix.groups[1].place, GroupPlacement::Domain(1));
        assert_eq!(mix.groups[2].place, GroupPlacement::Scatter);
        assert_eq!(mix.groups[3].place, GroupPlacement::Compact);
        assert_eq!(
            mix.label(),
            "ddot2:4@d0+dcopy:4@d1+stream:12@scatter+daxpy:4@compact+idle:2"
        );
        assert_eq!(Mix::parse(&mix.label()).unwrap(), mix);
    }

    #[test]
    fn remote_suffixes_roundtrip() {
        let mix = Mix::parse("dcopy:8@d0%r0.25+ddot2:8@d1%r0.1+stream:4@scatter+idle:2").unwrap();
        assert_eq!(mix.groups[0].remote_ppm, 250_000);
        assert!((mix.groups[0].remote_frac() - 0.25).abs() < 1e-12);
        assert_eq!(mix.groups[1].remote_ppm, 100_000);
        assert_eq!(mix.groups[2].remote_ppm, 0);
        assert!(mix.has_remote());
        assert_eq!(
            mix.label(),
            "dcopy:8@d0%r0.25+ddot2:8@d1%r0.1+stream:4@scatter+idle:2"
        );
        assert_eq!(Mix::parse(&mix.label()).unwrap(), mix);
        // %r without a placement suffix, and %r0 normalizing away.
        let bare = Mix::parse("dcopy:4%r0.5+ddot2:4%r0").unwrap();
        assert_eq!(bare.groups[0].remote_ppm, 500_000);
        assert_eq!(bare.groups[1].remote_ppm, 0);
        assert_eq!(bare.label(), "dcopy:4%r0.5+ddot2:4");
        // Builder equivalence.
        let built = Mix::new()
            .with_remote_on(KernelId::Dcopy, 4, GroupPlacement::Auto, 0.5)
            .with(KernelId::Ddot2, 4);
        assert_eq!(built, bare);
    }

    #[test]
    fn bound_suffixes_roundtrip() {
        // `@l3`/`@comp`/`@mem` parse in either order around a placement and
        // round-trip through the canonical label (place before bound).
        let mix = Mix::parse("jacobil3-v1:4@d0@l3+ddot1:2@comp+dcopy:4@mem+stream:4+idle:2")
            .unwrap();
        assert_eq!(mix.groups[0].bound, BoundHint::L3);
        assert_eq!(mix.groups[0].place, GroupPlacement::Domain(0));
        assert_eq!(mix.groups[1].bound, BoundHint::Compute);
        assert_eq!(mix.groups[2].bound, BoundHint::Mem);
        assert_eq!(mix.groups[3].bound, BoundHint::Auto);
        assert_eq!(
            mix.label(),
            "jacobil3-v1:4@d0@l3+ddot1:2@comp+dcopy:4@mem+stream:4+idle:2"
        );
        assert_eq!(Mix::parse(&mix.label()).unwrap(), mix);
        // Bound before placement and the long 'compute' spelling normalize.
        let flipped = Mix::parse("jacobil3-v1:4@l3@d0+ddot1:2@COMPUTE").unwrap();
        assert_eq!(flipped.groups[0].bound, BoundHint::L3);
        assert_eq!(flipped.groups[0].place, GroupPlacement::Domain(0));
        assert_eq!(flipped.groups[1].bound, BoundHint::Compute);
        assert_eq!(flipped.label(), "jacobil3-v1:4@d0@l3+ddot1:2@comp");
        // `@compact` stays a placement, not a truncated `@compute`.
        let compact = Mix::parse("dcopy:4@compact").unwrap();
        assert_eq!(compact.groups[0].place, GroupPlacement::Compact);
        assert_eq!(compact.groups[0].bound, BoundHint::Auto);
        // Builder equivalence.
        let built = Mix::new()
            .with_bound_on(KernelId::JacobiV1L3, 4, GroupPlacement::Domain(0), BoundHint::L3)
            .with_bound_on(KernelId::Ddot1, 2, GroupPlacement::Auto, BoundHint::Compute);
        assert_eq!(built, flipped);
    }

    /// Malformed or contradictory `@bound` suffixes surface as structured
    /// [`Error::MixParse`] with byte-accurate positions.
    #[test]
    fn bound_parse_errors_are_structured() {
        let case = |spec: &str, want_pos: usize, want_expected: &str| {
            match Mix::parse(spec).unwrap_err() {
                Error::MixParse { spec: s, pos, expected, .. } => {
                    assert_eq!(s, spec, "spec echoed");
                    assert_eq!(pos, want_pos, "position in '{spec}'");
                    assert!(
                        expected.contains(want_expected),
                        "'{spec}': expected token '{expected}' should mention '{want_expected}'"
                    );
                }
                other => panic!("'{spec}': wanted MixParse, got {other}"),
            }
        };
        // Unknown suffix token: the message now names both token classes.
        case("dcopy:4@l4", 8, "bound 'mem', 'l3' or 'comp'");
        // Duplicate bound, duplicate placement: position of the SECOND token.
        case("dcopy:4@l3@comp", 11, "at most one bound override");
        case("dcopy:4@d0@d1", 11, "at most one placement");
        case("dcopy:4@d0@l3@mem", 14, "at most one bound override");
        // Idle cores take no bound override.
        case("idle:2@l3", 0, "no bound override on idle cores");
        // Validation: @l3 needs a machine with l3_bw_gbs > 0, and excludes %r.
        let mut m = machine(MachineId::Rome);
        let l3mix = Mix::parse("jacobil3-v1:4@l3+dcopy:4").unwrap();
        l3mix.validate(&m).unwrap();
        m.l3_bw_gbs = 0.0;
        let e = l3mix.validate(&m).unwrap_err().to_string();
        assert!(e.contains("l3_bw_gbs"), "{e}");
        let e2 = Mix::parse("jacobil3-v1:4@l3%r0.25")
            .unwrap()
            .validate_bounds(120.0)
            .unwrap_err()
            .to_string();
        assert!(e2.contains("remote"), "{e2}");
    }

    #[test]
    fn default_remote_fills_only_unset_groups() {
        let mix = Mix::parse("dcopy:4%r0.5+ddot2:4+idle:2")
            .unwrap()
            .with_default_remote(0.25);
        assert_eq!(mix.groups[0].remote_ppm, 500_000, "explicit %r wins");
        assert_eq!(mix.groups[1].remote_ppm, 250_000, "default applied");
        assert_eq!(mix.idle_cores, 2);
    }

    /// Malformed `%r` suffixes surface as structured [`Error::MixParse`].
    #[test]
    fn remote_parse_errors_are_structured() {
        let case = |spec: &str, want_pos: usize, want_expected: &str| {
            match Mix::parse(spec).unwrap_err() {
                Error::MixParse { spec: s, pos, expected, .. } => {
                    assert_eq!(s, spec, "spec echoed");
                    assert_eq!(pos, want_pos, "position in '{spec}'");
                    assert!(
                        expected.contains(want_expected),
                        "'{spec}': expected token '{expected}' should mention '{want_expected}'"
                    );
                }
                other => panic!("'{spec}': wanted MixParse, got {other}"),
            }
        };
        case("dcopy:4%x0.2", 8, "remote fraction");
        case("dcopy:4%r", 8, "remote fraction");
        case("dcopy:4%r1.5", 8, "remote fraction");
        case("dcopy:4%r-0.1", 8, "remote fraction");
        case("dcopy:4@d0%rabc", 11, "remote fraction");
        case("idle:2%r0.1", 0, "idle");
        // Flat validation rejects remote mixes (they need a topology).
        let m = machine(MachineId::Rome);
        let e = Mix::parse("dcopy:4%r0.25").unwrap().validate(&m).unwrap_err().to_string();
        assert!(e.contains("topology"), "{e}");
    }

    #[test]
    fn validate_on_topology_checks_pins_and_capacity() {
        let m = machine(MachineId::Rome);
        let socket = Topology::socket(&m); // 4 domains x 8 cores
        let ok = Mix::parse("ddot2:4@d0+dcopy:4@d1+stream:12@scatter").unwrap();
        ok.validate_on(&socket, Placement::Compact).unwrap();
        // Out-of-range pin: d9 on a 4-domain socket.
        let oob = Mix::parse("dcopy:4@d9").unwrap();
        let e = oob.validate_on(&socket, Placement::Compact).unwrap_err().to_string();
        assert!(e.contains("d9"), "{e}");
        // Capacity: 9 cores cannot pin to one 8-core domain.
        assert!(Mix::parse("dcopy:9@d0")
            .unwrap()
            .validate_on(&socket, Placement::Compact)
            .is_err());
        // The whole socket is fine though.
        Mix::parse("dcopy:32")
            .unwrap()
            .validate_on(&socket, Placement::Scatter)
            .unwrap();
    }

    #[test]
    fn slugify_neutralizes_path_components() {
        assert_eq!(slugify("../../tmp/evil"), "tmp-evil");
        assert_eq!(slugify("demo"), "demo");
        assert_eq!(slugify("a b/c"), "a-b-c");
        assert_eq!(slugify("///"), "scenario");
        assert_eq!(
            Scenario::new("../x").file_stem(),
            "x",
            "scenario file stems cannot escape the output directory"
        );
    }

    #[test]
    fn validation_enforces_domain_and_activity() {
        let m = machine(MachineId::Rome); // 8 cores
        assert!(Mix::parse("dcopy:4+ddot2:4").unwrap().validate(&m).is_ok());
        assert!(Mix::parse("dcopy:5+ddot2:4").unwrap().validate(&m).is_err());
        assert!(Mix::parse("idle:4").unwrap().validate(&m).is_err());
        assert!(Mix::parse("dcopy:4+idle:5").unwrap().validate(&m).is_err());
    }

    #[test]
    fn pairing_case_is_k2_mix() {
        let case = PairingCase { k1: KernelId::Dcopy, k2: KernelId::Ddot2, n1: 6, n2: 4 };
        let mix = Mix::from_pairing(&case);
        assert_eq!(mix.k(), 2);
        assert_eq!(
            mix.groups[0],
            GroupSpec {
                kernel: KernelId::Dcopy,
                cores: 6,
                place: GroupPlacement::Auto,
                remote_ppm: 0,
                bound: BoundHint::Auto
            }
        );
        assert_eq!(
            mix.groups[1],
            GroupSpec {
                kernel: KernelId::Ddot2,
                cores: 4,
                place: GroupPlacement::Auto,
                remote_ppm: 0,
                bound: BoundHint::Auto
            }
        );
        assert_eq!(mix.idle_cores, 0);
    }

    #[test]
    fn scenario_parse_and_validate() {
        let m = machine(MachineId::Bdw1);
        let sc = Scenario::parse("t", "dcopy:4+ddot2:6 / dcopy:3+idle:7").unwrap();
        assert_eq!(sc.mixes.len(), 2);
        sc.validate(&m).unwrap();
        assert!(Scenario::parse("t", " / ").is_err());
    }

    #[test]
    fn demo_scenarios_fit_every_machine() {
        for mid in MachineId::ALL {
            let m = machine(mid);
            Scenario::demo(&m).validate(&m).unwrap();
        }
    }
}
