//! The kernel registry — all 15 kernels of the paper's Table II.

use crate::error::{Error, Result};
use crate::kernels::layer_condition::{jacobi_traffic, LayerCondition};
use crate::kernels::signature::{KernelClass, KernelSignature};

/// Identifiers of the Table II kernels.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum KernelId {
    /// `s += a[i]` — read-only reduction.
    VecSum,
    /// `s += a[i]*a[i]` — vector norm.
    Ddot1,
    /// `s += a[i]*b[i]` — dot product (the HPCG DDOT2).
    Ddot2,
    /// `s += a[i]*b[i]*c[i]`.
    Ddot3,
    /// `a[i] = s*a[i]`.
    Dscal,
    /// `a[i] = a[i] + s*b[i]`.
    Daxpy,
    /// `a[i] = b[i] + c[i]`.
    Add,
    /// `a[i] = b[i] + s*c[i]` — the STREAM triad (a.k.a. TRIAD in Fig. 9).
    Stream,
    /// `a[i] = r*b[i] + s*c[i]` (HPCG WAXPBY).
    Waxpby,
    /// `a[i] = b[i]`.
    Dcopy,
    /// `a[i] = b[i] + c[i]*d[i]` — Schoenauer triad.
    Schoenauer,
    /// Jacobi 2D 5-point, variant 1, layer condition fulfilled at L2.
    JacobiV1L2,
    /// Jacobi 2D 5-point, variant 1, layer condition fulfilled at L3 only.
    JacobiV1L3,
    /// Jacobi 2D 5-point, variant 2 (with RHS and residual), LC at L2.
    JacobiV2L2,
    /// Jacobi 2D 5-point, variant 2, LC at L3 only.
    JacobiV2L3,
}

impl KernelId {
    /// All kernels in Table II order.
    pub const ALL: [KernelId; 15] = [
        KernelId::VecSum,
        KernelId::Ddot1,
        KernelId::Ddot2,
        KernelId::Ddot3,
        KernelId::Dscal,
        KernelId::Daxpy,
        KernelId::Add,
        KernelId::Stream,
        KernelId::Waxpby,
        KernelId::Dcopy,
        KernelId::Schoenauer,
        KernelId::JacobiV1L2,
        KernelId::JacobiV1L3,
        KernelId::JacobiV2L2,
        KernelId::JacobiV2L3,
    ];

    /// Canonical lowercase key for CLI / file names.
    pub fn key(&self) -> &'static str {
        match self {
            KernelId::VecSum => "vecsum",
            KernelId::Ddot1 => "ddot1",
            KernelId::Ddot2 => "ddot2",
            KernelId::Ddot3 => "ddot3",
            KernelId::Dscal => "dscal",
            KernelId::Daxpy => "daxpy",
            KernelId::Add => "add",
            KernelId::Stream => "stream",
            KernelId::Waxpby => "waxpby",
            KernelId::Dcopy => "dcopy",
            KernelId::Schoenauer => "schoenauer",
            KernelId::JacobiV1L2 => "jacobil2-v1",
            KernelId::JacobiV1L3 => "jacobil3-v1",
            KernelId::JacobiV2L2 => "jacobil2-v2",
            KernelId::JacobiV2L3 => "jacobil3-v2",
        }
    }

    /// Parse a CLI name (case-insensitive, with paper aliases — `triad`
    /// means the STREAM triad, as in Fig. 9).
    pub fn parse(s: &str) -> Result<Self> {
        let k = s.to_ascii_lowercase();
        for id in KernelId::ALL {
            if id.key() == k {
                return Ok(id);
            }
        }
        match k.as_str() {
            "triad" => Ok(KernelId::Stream),
            "vectorsum" | "sum" => Ok(KernelId::VecSum),
            "copy" => Ok(KernelId::Dcopy),
            "jacobi-v1" | "jacobiv1" => Ok(KernelId::JacobiV1L2),
            "jacobi-v2" | "jacobiv2" => Ok(KernelId::JacobiV2L2),
            _ => Err(Error::UnknownKernel(s.to_string(), kernel_names().join(", "))),
        }
    }
}

/// Signature of one kernel (see Table II).
pub fn kernel(id: KernelId) -> KernelSignature {
    use KernelClass::*;
    match id {
        KernelId::VecSum => KernelSignature::streaming(
            "vecSUM", "s += a[i]", ReadOnly, 1, 0, 0, 1, 0, 1,
        ),
        KernelId::Ddot1 => KernelSignature::streaming(
            "DDOT1", "s += a[i]*a[i]", ReadOnly, 1, 0, 0, 1, 0, 2,
        ),
        KernelId::Ddot2 => KernelSignature::streaming(
            "DDOT2", "s += a[i]*b[i]", ReadOnly, 2, 0, 0, 2, 0, 2,
        ),
        KernelId::Ddot3 => KernelSignature::streaming(
            "DDOT3", "s += a[i]*b[i]*c[i]", ReadOnly, 3, 0, 0, 3, 0, 3,
        ),
        KernelId::Dscal => KernelSignature::streaming(
            "DSCAL", "a[i] = s*a[i]", ReadWrite, 1, 1, 0, 1, 1, 1,
        ),
        KernelId::Daxpy => KernelSignature::streaming(
            "DAXPY", "a[i] = a[i] + s*b[i]", ReadWrite, 2, 1, 0, 2, 1, 2,
        ),
        KernelId::Add => KernelSignature::streaming(
            "ADD", "a[i] = b[i] + c[i]", ReadWrite, 2, 1, 1, 2, 1, 1,
        ),
        KernelId::Stream => KernelSignature::streaming(
            "STREAM", "a[i] = b[i] + s*c[i]", ReadWrite, 2, 1, 1, 2, 1, 2,
        ),
        KernelId::Waxpby => KernelSignature::streaming(
            "WAXPBY", "a[i] = r*b[i] + s*c[i]", ReadWrite, 2, 1, 1, 2, 1, 3,
        ),
        KernelId::Dcopy => KernelSignature::streaming(
            "DCOPY", "a[i] = b[i]", ReadWrite, 1, 1, 1, 1, 1, 0,
        ),
        KernelId::Schoenauer => KernelSignature::streaming(
            "Schoenauer", "a[i] = b[i] + c[i]*d[i]", ReadWrite, 3, 1, 1, 3, 1, 2,
        ),
        KernelId::JacobiV1L2 => jacobi(id, 1, LayerCondition::FulfilledAtL2),
        KernelId::JacobiV1L3 => jacobi(id, 1, LayerCondition::FulfilledAtL3),
        KernelId::JacobiV2L2 => jacobi(id, 2, LayerCondition::FulfilledAtL2),
        KernelId::JacobiV2L3 => jacobi(id, 2, LayerCondition::FulfilledAtL3),
    }
}

/// Build the Jacobi stencil signatures (Table II footnotes §§/¶/†/‡).
fn jacobi(id: KernelId, variant: u8, lc: LayerCondition) -> KernelSignature {
    let (extra_reads, loads, stores, flops, name, body) = match variant {
        // b[j][i] = (a[j][i-1] + a[j][i+1] + a[j-1][i] + a[j+1][i]) * s
        1 => (0usize, 4usize, 1usize, 4usize, "Jacobi-v1", "b[j][i] = (a[W]+a[E]+a[N]+a[S]) * s"),
        // r1 = (ax*(A[W]+A[E]) + ay*(A[N]+A[S]) + b1*A[C] - F)/b1;
        // B = A - relax*r1; residual += r1*r1
        2 => (1usize, 6usize, 1usize, 13usize, "Jacobi-v2", "r1 = (ax*(A[W]+A[E]) + ay*(A[N]+A[S]) + b1*A[C] - F[C])/b1; B[C] = A[C] - relax*r1; res += r1*r1"),
        _ => unreachable!(),
    };
    let (mem, l3, l2) = jacobi_traffic(lc, extra_reads);
    let lc_tag = match lc {
        LayerCondition::FulfilledAtL2 => "LC_L2",
        LayerCondition::FulfilledAtL3 => "LC_L3",
        LayerCondition::Violated => "LC_violated",
    };
    // For stencils the paper reports code balance at the L3 level (the
    // memory-level balance is LC-independent).
    let l3_bytes_per_iter = l3.total() as f64 * crate::CACHE_LINE_BYTES / crate::ELEMS_PER_LINE as f64;
    KernelSignature {
        name: format!("{name} {lc_tag}"),
        body: body.to_string(),
        class: KernelClass::Stencil,
        mem,
        l3,
        l2,
        loads_per_iter: loads,
        stores_per_iter: stores,
        flops_per_iter: flops,
        code_balance: l3_bytes_per_iter / flops as f64,
        // Rename shadowing: `id` kept for potential future per-id tweaks.
    }
    .tap(id)
}

/// Identity helper so `jacobi` can keep its `id` parameter documented
/// without an unused-variable warning.
trait Tap: Sized {
    fn tap(self, _id: KernelId) -> Self {
        self
    }
}
impl Tap for KernelSignature {}

/// All kernels in Table II order.
pub fn all_kernels() -> Vec<(KernelId, KernelSignature)> {
    KernelId::ALL.iter().map(|&id| (id, kernel(id))).collect()
}

/// All kernel CLI keys.
pub fn kernel_names() -> Vec<&'static str> {
    KernelId::ALL.iter().map(|k| k.key()).collect()
}

/// The 10-kernel set used for the Fig. 8 / Fig. 9 pairing sweeps
/// ("vecSUM, DDOT2, DDOT3, DCOPY, Schoenauer, DAXPY, DSCAL, JacobiL2-v1,
/// JacobiL3-v1, and TRIAD").
pub fn pairing_set() -> Vec<KernelId> {
    vec![
        KernelId::VecSum,
        KernelId::Ddot2,
        KernelId::Ddot3,
        KernelId::Dcopy,
        KernelId::Schoenauer,
        KernelId::Daxpy,
        KernelId::Dscal,
        KernelId::JacobiV1L2,
        KernelId::JacobiV1L3,
        KernelId::Stream,
    ]
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table2_element_transfers() {
        // (kernel, expected total memory lines per unit) from Table II.
        let expect = [
            (KernelId::VecSum, 1),
            (KernelId::Ddot1, 1),
            (KernelId::Ddot2, 2),
            (KernelId::Ddot3, 3),
            (KernelId::Dscal, 2),
            (KernelId::Daxpy, 3),
            (KernelId::Add, 4),
            (KernelId::Stream, 4),
            (KernelId::Waxpby, 4),
            (KernelId::Dcopy, 3),
            (KernelId::Schoenauer, 5),
            (KernelId::JacobiV1L2, 3),
            (KernelId::JacobiV1L3, 3),
            (KernelId::JacobiV2L2, 4),
            (KernelId::JacobiV2L3, 4),
        ];
        for (id, lines) in expect {
            assert_eq!(kernel(id).mem.total(), lines, "{id:?}");
        }
    }

    #[test]
    fn table2_l3_transfers_for_stencils() {
        assert_eq!(kernel(KernelId::JacobiV1L2).l3.total(), 3);
        assert_eq!(kernel(KernelId::JacobiV1L3).l3.total(), 5);
        assert_eq!(kernel(KernelId::JacobiV2L2).l3.total(), 4);
        assert_eq!(kernel(KernelId::JacobiV2L3).l3.total(), 6);
    }

    #[test]
    fn table2_code_balance() {
        let cases = [
            (KernelId::VecSum, 8.0),
            (KernelId::Ddot1, 4.0),
            (KernelId::Ddot2, 8.0),
            (KernelId::Ddot3, 8.0),
            (KernelId::Dscal, 16.0),
            (KernelId::Daxpy, 12.0),
            (KernelId::Add, 32.0),
            (KernelId::Stream, 16.0),
            (KernelId::Waxpby, 32.0 / 3.0),
            (KernelId::Schoenauer, 20.0),
            (KernelId::JacobiV1L2, 6.0),
            (KernelId::JacobiV1L3, 10.0),
            (KernelId::JacobiV2L2, 32.0 / 13.0),
            (KernelId::JacobiV2L3, 48.0 / 13.0),
        ];
        for (id, want) in cases {
            let got = kernel(id).code_balance;
            assert!((got - want).abs() < 0.05, "{id:?}: B_c = {got}, want {want}");
        }
        assert!(kernel(KernelId::Dcopy).code_balance.is_infinite());
    }

    #[test]
    fn parse_aliases() {
        assert_eq!(KernelId::parse("TRIAD").unwrap(), KernelId::Stream);
        assert_eq!(KernelId::parse("ddot2").unwrap(), KernelId::Ddot2);
        assert!(KernelId::parse("spmv").is_err());
    }

    #[test]
    fn pairing_set_has_ten_distinct_kernels() {
        let set = pairing_set();
        assert_eq!(set.len(), 10);
        let mut dedup = set.clone();
        dedup.sort_by_key(|k| k.key());
        dedup.dedup();
        assert_eq!(dedup.len(), 10);
    }
}
