//! Scenario specifications: arbitrary k-group workload mixes and
//! time-phased sequences of mixes.
//!
//! A [`Mix`] generalizes [`crate::sweep::PairingCase`] from two thread
//! groups to any number of groups plus explicit idle cores (scenario (c) of
//! the paper's Fig. 2 — idle/communicating cores are simply absent from the
//! contention). A [`Scenario`] is a named sequence of mixes, modelling a
//! program that moves through workload phases (the desynchronization
//! phenomenology of Figs. 1–3: at any instant cores are spread over several
//! kernels and idle waits).
//!
//! Mixes have a compact text form for the CLI:
//! `"dcopy:4+ddot2:4+idle:2"`; scenarios join phases with `/`:
//! `"dcopy:8+ddot2:8 / dcopy:4+idle:12"`.

use crate::config::Machine;
use crate::error::{Error, Result};
use crate::kernels::KernelId;
use crate::sweep::PairingCase;

/// Reduce a user-supplied name to a safe file stem: `[A-Za-z0-9._-]` kept,
/// everything else (path separators, spaces, ...) mapped to `-`.
pub fn slugify(name: &str) -> String {
    let slug: String = name
        .chars()
        .map(|c| if c.is_ascii_alphanumeric() || matches!(c, '.' | '_' | '-') { c } else { '-' })
        .collect();
    let trimmed = slug.trim_matches(|c| c == '.' || c == '-').to_string();
    if trimmed.is_empty() {
        "scenario".to_string()
    } else {
        trimmed
    }
}

/// One group of cores all executing the same kernel.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct GroupSpec {
    /// Kernel the group runs.
    pub kernel: KernelId,
    /// Number of cores in the group.
    pub cores: usize,
}

/// An instantaneous workload mix: k kernel groups plus idle cores.
#[derive(Debug, Clone, Default, PartialEq, Eq)]
pub struct Mix {
    /// Kernel groups, in core order (group i occupies the cores after
    /// groups 0..i).
    pub groups: Vec<GroupSpec>,
    /// Cores that issue no memory traffic (idle or communicating).
    pub idle_cores: usize,
}

impl Mix {
    /// Start an empty mix (builder entry point).
    pub fn new() -> Self {
        Mix::default()
    }

    /// Add a kernel group of `cores` cores.
    pub fn with(mut self, kernel: KernelId, cores: usize) -> Self {
        self.groups.push(GroupSpec { kernel, cores });
        self
    }

    /// Add `cores` idle cores.
    pub fn idle(mut self, cores: usize) -> Self {
        self.idle_cores += cores;
        self
    }

    /// The k=2 special case: a pairing case as a mix.
    pub fn from_pairing(case: &PairingCase) -> Self {
        Mix::new().with(case.k1, case.n1).with(case.k2, case.n2)
    }

    /// Number of kernel groups (k).
    pub fn k(&self) -> usize {
        self.groups.len()
    }

    /// Cores executing kernels.
    pub fn active_cores(&self) -> usize {
        self.groups.iter().map(|g| g.cores).sum()
    }

    /// Active plus idle cores.
    pub fn total_cores(&self) -> usize {
        self.active_cores() + self.idle_cores
    }

    /// Distinct kernels appearing in the mix.
    pub fn kernels(&self) -> Vec<KernelId> {
        let mut ks: Vec<KernelId> = self.groups.iter().map(|g| g.kernel).collect();
        ks.sort_by_key(|k| k.key());
        ks.dedup();
        ks
    }

    /// Validate the mix against a machine's contention domain.
    pub fn validate(&self, m: &Machine) -> Result<()> {
        if self.active_cores() == 0 {
            return Err(Error::InvalidPlan(format!(
                "mix '{}' has no active cores",
                self.label()
            )));
        }
        if self.total_cores() > m.cores {
            return Err(Error::InvalidPlan(format!(
                "mix '{}' needs {} cores but the {} domain has {}",
                self.label(),
                self.total_cores(),
                m.name,
                m.cores
            )));
        }
        Ok(())
    }

    /// Canonical text form: `kernel:cores` joined by `+`, idle last.
    pub fn label(&self) -> String {
        let mut parts: Vec<String> = self
            .groups
            .iter()
            .map(|g| format!("{}:{}", g.kernel.key(), g.cores))
            .collect();
        if self.idle_cores > 0 {
            parts.push(format!("idle:{}", self.idle_cores));
        }
        parts.join("+")
    }

    /// Parse the text form (`"dcopy:4+ddot2:4+idle:2"`; whitespace around
    /// `+` is tolerated). Inverse of [`Mix::label`].
    pub fn parse(s: &str) -> Result<Self> {
        let mut mix = Mix::new();
        for part in s.split('+') {
            let part = part.trim();
            if part.is_empty() {
                continue;
            }
            let (name, count) = part.split_once(':').ok_or_else(|| {
                Error::InvalidPlan(format!("mix term '{part}' is not 'kernel:cores'"))
            })?;
            let cores: usize = count.trim().parse().map_err(|_| {
                Error::InvalidPlan(format!("bad core count in mix term '{part}'"))
            })?;
            if cores == 0 {
                return Err(Error::InvalidPlan(format!(
                    "mix term '{part}' has zero cores"
                )));
            }
            let name = name.trim();
            if name.eq_ignore_ascii_case("idle") {
                mix = mix.idle(cores);
            } else {
                mix = mix.with(KernelId::parse(name)?, cores);
            }
        }
        if mix.groups.is_empty() && mix.idle_cores == 0 {
            return Err(Error::InvalidPlan(format!("empty mix spec '{s}'")));
        }
        Ok(mix)
    }
}

/// A named, time-phased sequence of mixes. Each phase is measured at its own
/// steady state (the engines simulate stationary contention, matching the
/// sharing model's per-composition evaluation in the desync co-simulator).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Scenario {
    /// Display name (also used for result file names).
    pub name: String,
    /// Phases, in time order.
    pub mixes: Vec<Mix>,
}

impl Scenario {
    /// Start an empty scenario.
    pub fn new(name: &str) -> Self {
        Scenario { name: name.to_string(), mixes: Vec::new() }
    }

    /// Append a phase.
    pub fn then(mut self, mix: Mix) -> Self {
        self.mixes.push(mix);
        self
    }

    /// Parse a `/`-separated sequence of mix specs.
    pub fn parse(name: &str, s: &str) -> Result<Self> {
        let mixes = s
            .split('/')
            .filter(|p| !p.trim().is_empty())
            .map(Mix::parse)
            .collect::<Result<Vec<Mix>>>()?;
        if mixes.is_empty() {
            return Err(Error::InvalidPlan(format!("empty scenario spec '{s}'")));
        }
        Ok(Scenario { name: name.to_string(), mixes })
    }

    /// Validate every phase against a machine.
    pub fn validate(&self, m: &Machine) -> Result<()> {
        for mix in &self.mixes {
            mix.validate(m)?;
        }
        Ok(())
    }

    /// Safe file stem derived from the scenario name (see [`slugify`]).
    pub fn file_stem(&self) -> String {
        slugify(&self.name)
    }

    /// A built-in demo scenario scaled to a machine: a fully populated
    /// 3-group phase, a partially idle phase, and a 4-group phase.
    pub fn demo(m: &Machine) -> Self {
        let c = m.cores;
        let third = c / 3;
        let quarter = c / 4;
        Scenario::new("demo")
            .then(
                Mix::new()
                    .with(KernelId::Dcopy, third)
                    .with(KernelId::Ddot2, third)
                    .with(KernelId::Stream, c - 2 * third),
            )
            .then(
                Mix::new()
                    .with(KernelId::Dcopy, third)
                    .with(KernelId::Ddot2, third)
                    .idle(c - 2 * third),
            )
            .then(
                Mix::new()
                    .with(KernelId::VecSum, quarter.max(1))
                    .with(KernelId::Daxpy, quarter.max(1))
                    .with(KernelId::Schoenauer, quarter.max(1))
                    .with(KernelId::Dscal, c.saturating_sub(3 * quarter.max(1)).clamp(1, c)),
            )
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{machine, MachineId};

    #[test]
    fn builder_and_label_roundtrip() {
        let mix = Mix::new()
            .with(KernelId::Dcopy, 4)
            .with(KernelId::Ddot2, 4)
            .idle(2);
        assert_eq!(mix.k(), 2);
        assert_eq!(mix.active_cores(), 8);
        assert_eq!(mix.total_cores(), 10);
        assert_eq!(mix.label(), "dcopy:4+ddot2:4+idle:2");
        let back = Mix::parse(&mix.label()).unwrap();
        assert_eq!(back, mix);
    }

    #[test]
    fn parse_tolerates_whitespace_and_aliases() {
        let mix = Mix::parse(" triad:3 + IDLE:2 + ddot2:1 ").unwrap();
        assert_eq!(mix.groups[0].kernel, KernelId::Stream);
        assert_eq!(mix.idle_cores, 2);
        assert_eq!(mix.active_cores(), 4);
    }

    #[test]
    fn parse_rejects_malformed_specs() {
        assert!(Mix::parse("dcopy").is_err());
        assert!(Mix::parse("dcopy:x").is_err());
        assert!(Mix::parse("nosuchkernel:2").is_err());
        assert!(Mix::parse("").is_err());
        assert!(Mix::parse("dcopy:0+ddot2:4").is_err(), "zero-core groups are rejected");
        assert!(Mix::parse("idle:0").is_err());
    }

    #[test]
    fn slugify_neutralizes_path_components() {
        assert_eq!(slugify("../../tmp/evil"), "tmp-evil");
        assert_eq!(slugify("demo"), "demo");
        assert_eq!(slugify("a b/c"), "a-b-c");
        assert_eq!(slugify("///"), "scenario");
        assert_eq!(
            Scenario::new("../x").file_stem(),
            "x",
            "scenario file stems cannot escape the output directory"
        );
    }

    #[test]
    fn validation_enforces_domain_and_activity() {
        let m = machine(MachineId::Rome); // 8 cores
        assert!(Mix::parse("dcopy:4+ddot2:4").unwrap().validate(&m).is_ok());
        assert!(Mix::parse("dcopy:5+ddot2:4").unwrap().validate(&m).is_err());
        assert!(Mix::parse("idle:4").unwrap().validate(&m).is_err());
        assert!(Mix::parse("dcopy:4+idle:5").unwrap().validate(&m).is_err());
    }

    #[test]
    fn pairing_case_is_k2_mix() {
        let case = PairingCase { k1: KernelId::Dcopy, k2: KernelId::Ddot2, n1: 6, n2: 4 };
        let mix = Mix::from_pairing(&case);
        assert_eq!(mix.k(), 2);
        assert_eq!(mix.groups[0], GroupSpec { kernel: KernelId::Dcopy, cores: 6 });
        assert_eq!(mix.groups[1], GroupSpec { kernel: KernelId::Ddot2, cores: 4 });
        assert_eq!(mix.idle_cores, 0);
    }

    #[test]
    fn scenario_parse_and_validate() {
        let m = machine(MachineId::Bdw1);
        let sc = Scenario::parse("t", "dcopy:4+ddot2:6 / dcopy:3+idle:7").unwrap();
        assert_eq!(sc.mixes.len(), 2);
        sc.validate(&m).unwrap();
        assert!(Scenario::parse("t", " / ").is_err());
    }

    #[test]
    fn demo_scenarios_fit_every_machine() {
        for mid in MachineId::ALL {
            let m = machine(mid);
            Scenario::demo(&m).validate(&m).unwrap();
        }
    }
}
