//! Topology conformance suite.
//!
//! Two pins required by the topology layer:
//!
//! 1. **Degenerate equivalence** — every entry point run through a 1-domain
//!    [`Topology`] is bit-identical to its pre-topology single-domain path:
//!    same measured and modeled shares from the mix pipeline, same traces
//!    from the co-simulator. The topology layer must be a strict
//!    generalization, not a reimplementation.
//! 2. **Per-domain model fidelity** — on the 4-domain NPS4 Rome socket with
//!    independent per-domain mixes, every domain's bandwidth shares equal
//!    the paper's Eq. 5 evaluated over that domain's resident groups to
//!    1e-12, and domains are fully independent (a domain's results do not
//!    change when other domains are populated).

use membw::config::{machine, MachineId};
use membw::desync::{hpcg_program, CoSimConfig, CoSimEngine, HpcgVariant, NoiseModel};
use membw::scenario::{
    run_mixes, run_mixes_on, run_scenario, run_scenario_on, CharCache, CharSource, EngineKind,
    Mix, Scenario,
};
use membw::sweep::MeasureEngine;
use membw::topology::{Placement, Topology};

/// Mix pipeline, 1-domain topology: measured and modeled per-core values,
/// shares, and totals are bit-identical to `run_mixes` on every machine.
#[test]
fn degenerate_mix_pipeline_is_bit_identical() {
    for mid in MachineId::ALL {
        let m = machine(mid);
        let half = m.cores / 2;
        let mixes = vec![
            Mix::parse(&format!("dcopy:{}+ddot2:{}", half, m.cores - half)).unwrap(),
            Mix::parse(&format!("stream:{half}+idle:{}", m.cores - half)).unwrap(),
        ];
        let flat = run_mixes(&m, &mixes, &MeasureEngine::Fluid).unwrap();
        let topo = Topology::single(&m);
        for placement in [Placement::Compact, Placement::Scatter] {
            let placed = run_mixes_on(&topo, placement, &mixes, &MeasureEngine::Fluid).unwrap();
            for (t, f) in placed.cases.iter().zip(&flat.cases) {
                assert_eq!(t.domain_ids, vec![0], "{mid:?}: one active domain");
                assert_eq!(t.domains[0].mix, f.mix, "{mid:?}: sub-mix is the mix");
                assert_eq!(
                    t.measured_total_gbs.to_bits(),
                    f.measured_total_gbs.to_bits(),
                    "{mid:?}: measured total"
                );
                assert_eq!(t.model_total_gbs.to_bits(), f.model_total_gbs.to_bits());
                for (a, b) in t.domains[0].groups.iter().zip(&f.groups) {
                    assert_eq!(a.measured_per_core.to_bits(), b.measured_per_core.to_bits());
                    assert_eq!(a.model_per_core.to_bits(), b.model_per_core.to_bits());
                    assert_eq!(a.model_alpha.to_bits(), b.model_alpha.to_bits());
                }
                for (a, b) in t.socket.iter().zip(&f.groups) {
                    assert_eq!(a.measured_bw_gbs.to_bits(), b.measured_bw_gbs.to_bits());
                    assert_eq!(a.model_bw_gbs.to_bits(), b.model_bw_gbs.to_bits());
                }
            }
        }
    }
}

/// Scenario pipeline, 1-domain topology: phase-by-phase equivalence.
#[test]
fn degenerate_scenario_pipeline_is_bit_identical() {
    let m = machine(MachineId::Bdw1);
    let sc = Scenario::parse("conf", "dcopy:4+ddot2:6 / dcopy:3+idle:7").unwrap();
    let flat = run_scenario(&m, &sc, &MeasureEngine::Fluid).unwrap();
    let placed =
        run_scenario_on(&Topology::single(&m), Placement::Compact, &sc, &MeasureEngine::Fluid)
            .unwrap();
    assert_eq!(placed.phases.len(), flat.phases.len());
    for (t, f) in placed.phases.iter().zip(&flat.phases) {
        for (a, b) in t.socket.iter().zip(&f.groups) {
            assert_eq!(a.measured_per_core.to_bits(), b.measured_per_core.to_bits());
            assert_eq!(a.model_per_core.to_bits(), b.model_per_core.to_bits());
        }
    }
}

/// Co-simulation, 1-domain topology: noisy Fig. 3-style run produces a
/// bit-identical trace through `with_topology` and the plain engine.
#[test]
fn degenerate_cosim_trace_is_bit_identical() {
    let m = machine(MachineId::Clx);
    let prog = hpcg_program(HpcgVariant::Modified, 48, 2);
    let cfg = CoSimConfig {
        dt_s: 20e-6,
        t_max_s: 600.0,
        initial_stagger_s: 0.2e-3,
        neighbor_radius: 3,
        noise: NoiseModel::mild(7),
    };
    let plain = CoSimEngine::new(&m, prog.clone(), 10, cfg.clone()).unwrap();
    let placed = CoSimEngine::with_topology(
        &m,
        &Topology::single(&m),
        Placement::Compact,
        prog,
        10,
        cfg,
        &CharSource::Ecm,
    )
    .unwrap();
    let (a, b) = (plain.run(), placed.run());
    assert_eq!(a.events, b.events);
    assert_eq!(a.trace.records.len(), b.trace.records.len());
    for (x, y) in a.trace.records.iter().zip(&b.trace.records) {
        assert_eq!(x.rank, y.rank);
        assert_eq!(x.label, y.label);
        assert_eq!(x.t_start.to_bits(), y.t_start.to_bits());
        assert_eq!(x.t_end.to_bits(), y.t_end.to_bits());
    }
    for (x, y) in a.finish_s.iter().zip(&b.finish_s) {
        assert_eq!(x.to_bits(), y.to_bits());
    }
}

/// 4-domain Rome socket, independent per-domain mixes: every domain's
/// model shares reproduce Eq. 5 (`α_i = n_i f_i / Σ n_k f_k`) over that
/// domain's resident groups to 1e-12.
#[test]
fn rome_socket_reproduces_per_domain_eq5_shares() {
    let m = machine(MachineId::Rome);
    let topo = Topology::socket(&m);
    // Four different two-group pairings, one per ccNUMA domain.
    let mix = Mix::parse(
        "dcopy:4@d0+ddot2:4@d0+stream:4@d1+daxpy:4@d1+vecsum:4@d2+dscal:4@d2+waxpby:4@d3+ddot1:4@d3",
    )
    .unwrap();
    let rs = run_mixes_on(&topo, Placement::Compact, &[mix], &MeasureEngine::Fluid).unwrap();
    let case = &rs.cases[0];
    assert_eq!(case.domain_ids, vec![0, 1, 2, 3]);
    let chars = |k| {
        CharCache::global()
            .lookup(&(m.id, k, EngineKind::Fluid))
            .expect("characterized by run_mixes_on")
    };
    for dr in &case.domains {
        assert!(dr.saturated, "8 Rome cores saturate the domain");
        let nf: Vec<f64> = dr.groups.iter().map(|g| g.n as f64 * chars(g.kernel).f).collect();
        let total: f64 = nf.iter().sum();
        for (g, nf_i) in dr.groups.iter().zip(&nf) {
            let eq5 = nf_i / total;
            assert!(
                (g.model_alpha - eq5).abs() < 1e-12,
                "{:?}: alpha {} vs Eq.5 {}",
                g.kernel,
                g.model_alpha,
                eq5
            );
        }
    }
}

/// Domains are independent end to end: domain 0's measured and modeled
/// results do not change when the other three domains get populated.
#[test]
fn rome_socket_domains_are_independent() {
    let m = machine(MachineId::Rome);
    let topo = Topology::socket(&m);
    let solo = Mix::parse("dcopy:4@d0+ddot2:4@d0").unwrap();
    let full = Mix::parse(
        "dcopy:4@d0+ddot2:4@d0+stream:8@d1+daxpy:8@d2+schoenauer:4@d3+idle:4",
    )
    .unwrap();
    let a = run_mixes_on(&topo, Placement::Compact, &[solo], &MeasureEngine::Fluid).unwrap();
    let b = run_mixes_on(&topo, Placement::Compact, &[full], &MeasureEngine::Fluid).unwrap();
    let (d0_solo, d0_full) = (&a.cases[0].domains[0], &b.cases[0].domains[0]);
    assert_eq!(d0_solo.groups.len(), d0_full.groups.len());
    for (x, y) in d0_solo.groups.iter().zip(&d0_full.groups) {
        assert_eq!(x.kernel, y.kernel);
        assert_eq!(x.measured_per_core.to_bits(), y.measured_per_core.to_bits());
        assert_eq!(x.model_per_core.to_bits(), y.model_per_core.to_bits());
        assert_eq!(x.model_alpha.to_bits(), y.model_alpha.to_bits());
    }
}

/// Full-socket HPCG co-simulation: with identical per-domain composition
/// the 32-rank socket behaves like four copies of the 8-rank domain.
#[test]
fn rome_socket_cosim_matches_single_domain_per_domain() {
    let m = machine(MachineId::Rome);
    let prog = hpcg_program(HpcgVariant::Plain, 48, 2);
    let cfg = CoSimConfig { dt_s: 50e-6, t_max_s: 600.0, ..Default::default() };
    let solo = CoSimEngine::new(&m, prog.clone(), 8, cfg.clone()).unwrap().run();
    let topo = Topology::socket(&m);
    let socket = CoSimEngine::with_topology(
        &m,
        &topo,
        Placement::Compact,
        prog,
        32,
        cfg,
        &CharSource::Ecm,
    )
    .unwrap()
    .run();
    assert!(socket.finish_s.iter().all(|f| f.is_finite()));
    assert_eq!(socket.trace.records.len(), 4 * solo.trace.records.len());
    // Lockstep start, no noise, same composition everywhere: every rank of
    // the socket finishes when the 8-rank domain run does.
    let want = solo.finish_s[0];
    for (r, fin) in socket.finish_s.iter().enumerate() {
        assert!(
            (fin - want).abs() <= 1e-12 * want.abs(),
            "rank {r}: {fin} vs single-domain {want}"
        );
    }
}
