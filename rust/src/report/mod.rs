//! Report layer: regenerates every table and figure of the paper as text
//! (ASCII) plus machine-readable CSV under a results directory.
//!
//! One function per experiment; the CLI (`repro experiment <id>`) and the
//! bench harness call these.

mod experiments;
mod optimizer;
mod scenario;
mod service;
mod table;

pub use experiments::{
    ablation_report, fig1_report, fig3_report, fig4_report, fig6_report, fig7_report, fig8_report, fig9_report,
    table1_report, table2_report, ExperimentCtx,
};
pub use optimizer::optimizer_report;
pub use scenario::{scenario_report, topology_scenario_report};
pub use service::serve_report;
pub use table::AsciiTable;
