//! Time-stepped fluid-queueing simulator of the memory interface.
//!
//! Physics per cycle (mirrored exactly by the JAX/Pallas artifact —
//! `python/compile/kernels/contention.py`; keep the two in sync!):
//!
//! 1. **Service**: the interface drains queued requests proportionally to
//!    per-core queue occupancy, limited by capacity `C` in *cost* units
//!    (write lines cost extra): `λ = min(1, C / Σ o_i c_i)`,
//!    `served_i = λ o_i`.
//! 2. **Prefetch depth** (the paper's Fig. 5 mechanism): core `i` keeps at
//!    most `W_i = D0 + β d_i c_i L0` requests queued — the bandwidth-delay
//!    product of its intrinsic demand. Higher-f kernels queue more requests
//!    and therefore obtain a larger share; the additive floor `D0` slightly
//!    compresses shares towards equality, one of the real second-order
//!    effects the analytic model ignores.
//! 3. **Issue**: `o_i += min(d_i, max(0, W_i − o_i))` — rate-limited by the
//!    core's intrinsic demand `d_i = mem_lines / T_ECM` and window-limited
//!    by `W_i`.
//!
//! Steady states (derivable by hand, asserted in tests):
//! * solo core: `served = d`, i.e. `b_1 = f·b_s` — the ECM value;
//! * saturated domain: `served_i ∝ W_i ≈ ∝ d_i c_i ∝ f_i`, total cost
//!   throughput `= C` — approximately the paper's Eqs. (4)+(5), with
//!   deviations from the `D0` floor and the flow-weighted (rather than
//!   thread-weighted) service mix.

use crate::config::Machine;
use crate::simulator::network::{IfaceNet, NetFluidSimulator, NetStream};
use crate::simulator::workload::CoreWorkload;

/// Configuration of one fluid simulation run.
#[derive(Debug, Clone)]
pub struct FluidConfig {
    /// Warm-up cycles before measurement starts.
    pub warmup_cycles: usize,
    /// Measured cycles.
    pub measure_cycles: usize,
}

impl Default for FluidConfig {
    fn default() -> Self {
        // Queues fill within W/d ≈ a few hundred cycles; 4k warm-up + 12k
        // measurement matches the AOT artifact geometry and agrees with a
        // 20k/60k run to <0.1% (validated by `prop_fluid_cycle_convergence`).
        FluidConfig { warmup_cycles: 4_096, measure_cycles: 12_288 }
    }
}

/// Result of a fluid simulation.
#[derive(Debug, Clone)]
pub struct FluidResult {
    /// Per-core memory bandwidth, GB/s.
    pub per_core_gbs: Vec<f64>,
    /// Aggregate memory bandwidth, GB/s.
    pub total_gbs: f64,
    /// Mean interface utilization during measurement (0..1).
    pub utilization: f64,
}

impl FluidResult {
    /// Aggregate bandwidth of one workload group, GB/s.
    pub fn group_bw(&self, workloads: &[CoreWorkload], group: usize) -> f64 {
        self.per_core_gbs
            .iter()
            .zip(workloads)
            .filter(|(_, w)| w.group == group)
            .map(|(bw, _)| bw)
            .sum()
    }

    /// Mean per-core bandwidth of one group, GB/s.
    pub fn group_per_core(&self, workloads: &[CoreWorkload], group: usize) -> f64 {
        let n = workloads.iter().filter(|w| w.group == group).count();
        if n == 0 {
            0.0
        } else {
            self.group_bw(workloads, group) / n as f64
        }
    }
}

/// The fluid simulator.
pub struct FluidSimulator<'a> {
    machine: &'a Machine,
    config: FluidConfig,
}

impl<'a> FluidSimulator<'a> {
    /// Create a simulator for `machine`.
    pub fn new(machine: &'a Machine, config: FluidConfig) -> Self {
        FluidSimulator { machine, config }
    }

    /// Target prefetch depth (queued-request window) of a workload on this
    /// machine: `W = D0 + β d c L0`.
    pub fn window(&self, w: &CoreWorkload) -> f64 {
        let q = &self.machine.queue;
        q.depth_floor + q.depth_beta * w.demand_lines_per_cy * w.cost_factor * q.base_latency_cy
    }

    /// Run the per-cycle fluid model for the given per-core workloads
    /// (one entry per core; use [`CoreWorkload::idle`] for idle cores).
    ///
    /// This is the degenerate one-interface case of the multi-interface
    /// engine ([`crate::simulator::NetFluidSimulator`]): every core is one
    /// home portion of weight 1 on a single-memory-interface network. The
    /// delegation is bit-identical to the seed fused loop (pinned by a
    /// verbatim reference copy in `rust/tests/simulator_conformance.rs`).
    pub fn run(&self, workloads: &[CoreWorkload]) -> FluidResult {
        let m = self.machine;
        let n = workloads.len();
        assert!(n <= m.cores, "more workloads ({n}) than cores ({})", m.cores);

        let net = IfaceNet::single(m);
        let streams: Vec<NetStream> = workloads
            .iter()
            .map(|&w| NetStream { workload: w, home: 0, remote_frac: 0.0, l3_frac: 0.0 })
            .collect();
        let r = NetFluidSimulator::new(&net, self.config.clone()).run(&streams);
        let total_gbs = r.per_stream_gbs.iter().sum();
        FluidResult {
            per_core_gbs: r.per_stream_gbs,
            total_gbs,
            utilization: r.mem_utilization[0],
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{machine, MachineId};
    use crate::kernels::{kernel, KernelId};

    fn wl(k: KernelId, mid: MachineId, group: usize) -> CoreWorkload {
        CoreWorkload::from_kernel(&kernel(k), &machine(mid), group)
    }

    #[test]
    fn solo_core_runs_at_ecm_speed() {
        // One core alone: bandwidth = f * b_s (the ECM single-core value).
        for mid in MachineId::ALL {
            let m = machine(mid);
            let sim = FluidSimulator::new(&m, FluidConfig::default());
            let w = wl(KernelId::Stream, mid, 0);
            let r = sim.run(&[w]);
            let p = crate::ecm::predict(&kernel(KernelId::Stream), &m);
            let err = (r.per_core_gbs[0] - p.b1_gbs).abs() / p.b1_gbs;
            assert!(err < 0.03, "{mid:?}: sim {} vs ECM {}", r.per_core_gbs[0], p.b1_gbs);
        }
    }

    #[test]
    fn full_domain_saturates_near_bs() {
        for mid in MachineId::ALL {
            let m = machine(mid);
            let sim = FluidSimulator::new(&m, FluidConfig::default());
            let w = wl(KernelId::Ddot2, mid, 0);
            let ws = vec![w; m.cores];
            let r = sim.run(&ws);
            let bs = m.saturated_bw(0.0, 2);
            let err = (r.total_gbs - bs).abs() / bs;
            assert!(err < 0.06, "{mid:?}: total {} vs b_s {}", r.total_gbs, bs);
            assert!(r.utilization > 0.9, "{mid:?}: utilization {}", r.utilization);
        }
    }

    #[test]
    fn bandwidth_conserved_and_nonnegative() {
        let m = machine(MachineId::Bdw1);
        let sim = FluidSimulator::new(&m, FluidConfig::default());
        let mut ws = vec![wl(KernelId::Dcopy, MachineId::Bdw1, 0); 6];
        ws.extend(vec![wl(KernelId::Ddot2, MachineId::Bdw1, 1); 4]);
        let r = sim.run(&ws);
        assert!(r.per_core_gbs.iter().all(|&b| b >= 0.0));
        // Total cannot exceed the read-only capacity.
        assert!(r.total_gbs <= m.read_bw_gbs * 1.001);
        // Groups partition the total.
        let g0 = r.group_bw(&ws, 0);
        let g1 = r.group_bw(&ws, 1);
        assert!((g0 + g1 - r.total_gbs).abs() < 1e-6);
    }

    #[test]
    fn higher_f_kernel_gets_larger_per_core_share() {
        // DCOPY has higher f than DDOT2 on every Intel machine (Table II):
        // at 5+5 on a saturated domain its cores must obtain more bandwidth.
        let m = machine(MachineId::Bdw1);
        let sim = FluidSimulator::new(&m, FluidConfig::default());
        let mut ws = vec![wl(KernelId::Dcopy, MachineId::Bdw1, 0); 5];
        ws.extend(vec![wl(KernelId::Ddot2, MachineId::Bdw1, 1); 5]);
        let r = sim.run(&ws);
        let per0 = r.group_per_core(&ws, 0);
        let per1 = r.group_per_core(&ws, 1);
        let f0 = wl(KernelId::Dcopy, MachineId::Bdw1, 0).f_ecm;
        let f1 = wl(KernelId::Ddot2, MachineId::Bdw1, 1).f_ecm;
        assert!(f0 > f1, "precondition: f_DCOPY > f_DDOT2");
        assert!(per0 > per1, "DCOPY per-core {per0} !> DDOT2 per-core {per1}");
    }

    #[test]
    fn sim_matches_analytic_model_within_paper_band() {
        // The headline check, previewing Fig. 8: the analytic model (Eqs.
        // 4+5 with ECM-derived f and b_s) predicts the simulated per-core
        // bandwidth to better than 8%.
        use crate::sharing::{share_two_groups, KernelGroup};
        let m = machine(MachineId::Bdw1);
        let sim = FluidSimulator::new(&m, FluidConfig::default());
        let mut ws = vec![wl(KernelId::Dcopy, MachineId::Bdw1, 0); 6];
        ws.extend(vec![wl(KernelId::Ddot2, MachineId::Bdw1, 1); 4]);
        let r = sim.run(&ws);

        let p_dcopy = crate::ecm::predict(&kernel(KernelId::Dcopy), &m);
        let p_ddot2 = crate::ecm::predict(&kernel(KernelId::Ddot2), &m);
        let pred = share_two_groups(
            &KernelGroup { n: 6, f: p_dcopy.f, bs_gbs: p_dcopy.bs_gbs },
            &KernelGroup { n: 4, f: p_ddot2.f, bs_gbs: p_ddot2.bs_gbs },
        );
        for (g, sim_pc) in [(0usize, r.group_per_core(&ws, 0)), (1, r.group_per_core(&ws, 1))] {
            let err = (sim_pc - pred.per_core_gbs[g]).abs() / pred.per_core_gbs[g];
            assert!(err < 0.08, "group {g}: sim {sim_pc} vs model {}", pred.per_core_gbs[g]);
        }
    }

    #[test]
    fn idle_cores_free_bandwidth_for_active_ones() {
        let m = machine(MachineId::Bdw2);
        let sim = FluidSimulator::new(&m, FluidConfig::default());
        let full: Vec<_> = vec![wl(KernelId::Stream, MachineId::Bdw2, 0); m.cores];
        let r_full = sim.run(&full);
        let mut half: Vec<_> = vec![wl(KernelId::Stream, MachineId::Bdw2, 0); m.cores / 2];
        half.extend(vec![CoreWorkload::idle(); m.cores - m.cores / 2]);
        let r_half = sim.run(&half);
        assert!(r_half.per_core_gbs[0] > r_full.per_core_gbs[0]);
    }
}
