//! Placement / co-schedule search engine.
//!
//! The analytic model rates a placement in microseconds, which makes it
//! viable as the *inner loop of a search* rather than just a predictor.
//! This module turns it into one:
//!
//! * [`space`] — the search space: per-group home domains and remote
//!   fractions, pins and capacity constraints, neighborhood moves
//!   (migrate / swap / retune), deterministic start candidates.
//! * [`delta`] — incremental re-rating: a move re-solves only the
//!   interfaces whose member portions changed, bit-identical to a full
//!   [`crate::sharing::share_remote`] re-solve (gated placements fall
//!   back to the full fixed point).
//! * [`memo`] — a sharded, concurrency-safe candidate → score memo so
//!   parallel scoring threads neither serialize nor thrash; namespaced
//!   by [`SearchSpace::fingerprint`] so one process-wide memo can stay
//!   warm across the searches of a `repro serve` session.
//! * [`search`] — the multi-start beam driver with batched parallel
//!   scoring and fixed-seed determinism; objectives: aggregate
//!   throughput, makespan (finalists re-ranked by
//!   [`crate::timeline::simulate_placed`]), max-interference.
//! * [`pairing`] — model-guided pairing of a task queue onto one
//!   domain (the `task_scheduler` example's policy, beam-generalized).
//!
//! The headline metric is raw evaluation throughput (placements
//! scored per second): `repro bench` measures delta + parallel + memo
//! against a sequential full-re-solve baseline into
//! `results/BENCH_optimizer.json`, and `repro optimize` exposes the
//! search on the CLI. See `docs/OPTIMIZER.md` for the worked example.

pub mod delta;
pub mod memo;
pub mod pairing;
pub mod search;
pub mod space;

pub use delta::{DeltaEval, DeltaStats, EvalOutcome};
pub use memo::ShardedScoreMemo;
pub use pairing::{plan_pairing, PairPlan, PairTask};
pub use search::{optimize, optimize_with_memo, Objective, OptResult, SearchConfig, TraceStep};
pub use space::{Candidate, Move, OptGroup, SearchSpace, DEFAULT_REMOTE_LEVELS};
