//! Line-granularity discrete-event simulator of a memory contention domain.
//!
//! Higher-fidelity reference implementation of the same physics as
//! [`crate::simulator::FluidSimulator`]:
//!
//! * each core generates one *integer* cache-line request every
//!   `1/d` cycles (with a small jitter to break phase locking), but only
//!   while its outstanding-request count is below its prefetch window
//!   `W = D0 + β d c L0`;
//! * a single memory server serves one line at a time; the service time of
//!   a line is `c / C` cycles (write lines cost more);
//! * the next line to serve is drawn by a weighted lottery over cores,
//!   weighted by queue occupancy — a stochastic approximation of FR-FCFS
//!   arbitration that matches the fluid model's proportional-share rule in
//!   expectation.
//!
//! The DES adds discretization and stochastic arbitration noise on top of
//! the fluid model — `cargo test` cross-validates the two (they agree to a
//! few percent), and the PJRT artifact is validated against both.

use crate::config::Machine;
use crate::simulator::network::{IfaceNet, NetDesSimulator, NetStream};
use crate::simulator::workload::CoreWorkload;

/// Configuration of a DES run.
#[derive(Debug, Clone)]
pub struct DesConfig {
    /// Warm-up cycles before measurement.
    pub warmup_cycles: f64,
    /// Measured cycles.
    pub measure_cycles: f64,
    /// RNG seed (lottery + jitter).
    pub seed: u64,
}

impl Default for DesConfig {
    fn default() -> Self {
        DesConfig { warmup_cycles: 40_000.0, measure_cycles: 400_000.0, seed: 0xB4D5EED }
    }
}

/// Result of a DES run.
#[derive(Debug, Clone)]
pub struct DesResult {
    /// Per-core memory bandwidth, GB/s.
    pub per_core_gbs: Vec<f64>,
    /// Aggregate bandwidth, GB/s.
    pub total_gbs: f64,
    /// Fraction of measured time the memory server was busy.
    pub utilization: f64,
    /// Total line-service events processed (for perf accounting).
    pub events: u64,
}

impl DesResult {
    /// Mean per-core bandwidth of one group, GB/s.
    pub fn group_per_core(&self, workloads: &[CoreWorkload], group: usize) -> f64 {
        let sel: Vec<f64> = self
            .per_core_gbs
            .iter()
            .zip(workloads)
            .filter(|(_, w)| w.group == group)
            .map(|(&bw, _)| bw)
            .collect();
        if sel.is_empty() {
            0.0
        } else {
            sel.iter().sum::<f64>() / sel.len() as f64
        }
    }
}

/// The discrete-event simulator.
pub struct DesSimulator<'a> {
    machine: &'a Machine,
    config: DesConfig,
}

impl<'a> DesSimulator<'a> {
    /// Create a DES for `machine`.
    pub fn new(machine: &'a Machine, config: DesConfig) -> Self {
        DesSimulator { machine, config }
    }

    /// Run the DES for the given per-core workloads.
    ///
    /// This is the degenerate one-interface case of the multi-interface
    /// engine ([`crate::simulator::NetDesSimulator`]): one component, one
    /// memory server, every core a whole-stream portion. The delegation is
    /// bit-identical to the seed event loop — same xorshift draw sequence,
    /// same heap tie-breaking (pinned by a verbatim reference copy in
    /// `rust/tests/simulator_conformance.rs`).
    pub fn run(&self, workloads: &[CoreWorkload]) -> DesResult {
        let m = self.machine;
        assert!(workloads.len() <= m.cores);
        let net = IfaceNet::single(m);
        let streams: Vec<NetStream> = workloads
            .iter()
            .map(|&w| NetStream { workload: w, home: 0, remote_frac: 0.0, l3_frac: 0.0 })
            .collect();
        let r = NetDesSimulator::new(&net, self.config.clone()).run(&streams);
        let total_gbs = r.per_stream_gbs.iter().sum();
        DesResult {
            per_core_gbs: r.per_stream_gbs,
            total_gbs,
            utilization: r.mem_utilization[0],
            events: r.events,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{machine, MachineId};
    use crate::kernels::{kernel, KernelId};
    use crate::simulator::fluid::{FluidConfig, FluidSimulator};

    fn wl(k: KernelId, mid: MachineId, group: usize) -> CoreWorkload {
        CoreWorkload::from_kernel(&kernel(k), &machine(mid), group)
    }

    #[test]
    fn solo_core_matches_ecm() {
        let m = machine(MachineId::Bdw1);
        let des = DesSimulator::new(&m, DesConfig::default());
        let r = des.run(&[wl(KernelId::Stream, MachineId::Bdw1, 0)]);
        let p = crate::ecm::predict(&kernel(KernelId::Stream), &m);
        let err = (r.per_core_gbs[0] - p.b1_gbs).abs() / p.b1_gbs;
        assert!(err < 0.05, "DES {} vs ECM {}", r.per_core_gbs[0], p.b1_gbs);
    }

    #[test]
    fn saturates_full_domain() {
        let m = machine(MachineId::Clx);
        let des = DesSimulator::new(&m, DesConfig::default());
        let ws = vec![wl(KernelId::Stream, MachineId::Clx, 0); m.cores];
        let r = des.run(&ws);
        let bs = m.saturated_bw(0.25, 4);
        let err = (r.total_gbs - bs).abs() / bs;
        assert!(err < 0.06, "DES total {} vs b_s {}", r.total_gbs, bs);
        assert!(r.utilization > 0.95);
    }

    #[test]
    fn des_agrees_with_fluid_on_pairings() {
        // Cross-validation of the two measurement engines.
        let m = machine(MachineId::Bdw1);
        let des = DesSimulator::new(&m, DesConfig::default());
        let fluid = FluidSimulator::new(&m, FluidConfig::default());
        let mut ws = vec![wl(KernelId::Dcopy, MachineId::Bdw1, 0); 6];
        ws.extend(vec![wl(KernelId::Ddot2, MachineId::Bdw1, 1); 4]);
        let rd = des.run(&ws);
        let rf = fluid.run(&ws);
        for g in 0..2 {
            let a = rd.group_per_core(&ws, g);
            let b = rf.group_per_core(&ws, g);
            let err = (a - b).abs() / b;
            assert!(err < 0.06, "group {g}: DES {a} vs fluid {b}");
        }
    }

    #[test]
    fn deterministic_given_seed() {
        let m = machine(MachineId::Rome);
        let ws = vec![wl(KernelId::Daxpy, MachineId::Rome, 0); 4];
        let cfg = DesConfig { measure_cycles: 50_000.0, ..Default::default() };
        let a = DesSimulator::new(&m, cfg.clone()).run(&ws);
        let b = DesSimulator::new(&m, cfg).run(&ws);
        assert_eq!(a.per_core_gbs, b.per_core_gbs);
    }
}
