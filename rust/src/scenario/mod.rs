//! Scenario engine: arbitrary k-group workload mixes measured through one
//! batched, parallel pipeline.
//!
//! The paper derives its sharing model (Eqs. 4–5) for *pairs* of kernels,
//! but its own desynchronization phenomenology (Figs. 1–3) has cores spread
//! over many kernels plus idle phases at once. [`crate::sharing`] already
//! generalizes the model analytically to k groups; this subsystem makes the
//! k-group space *measurable*:
//!
//! * `spec` — [`Mix`] (k kernel groups + idle cores, with a builder and a
//!   compact text form) and [`Scenario`] (a named, time-phased sequence of
//!   mixes),
//! * [`cache`] — the process-wide kernel-characterization cache shared by
//!   every measurement pipeline, with hit/miss accounting,
//! * `runner` — [`run_mixes`]/[`run_scenario`]: batched execution on the
//!   fluid, DES, or PJRT engine, parallelized over a dependency-free worker
//!   pool, with the multigroup prediction attached to every case; and
//!   [`run_mixes_on`]/[`run_scenario_on`]: the same pipeline over a
//!   multi-domain [`crate::topology::Topology`] — mixes are resolved onto
//!   ccNUMA domains by a [`crate::topology::Placement`] and each domain is
//!   measured and modeled independently,
//! * `results` — per-group measured-vs-model records with CSV/JSONL
//!   emission.
//!
//! The legacy two-group pairing sweep ([`crate::sweep`]) is the k=2 special
//! case: [`crate::sweep::run_cases`] converts each
//! [`crate::sweep::PairingCase`] into a [`Mix`] and delegates here, so there
//! is exactly one measurement pipeline.
//!
//! # Examples
//!
//! The mix DSL round-trips through [`Mix::parse`] / [`Mix::label`],
//! including `@` placement and `%r` remote-access suffixes:
//!
//! ```
//! use membw::scenario::Mix;
//!
//! let mix = Mix::parse("dcopy:8@d0%r0.25+ddot2:8@d1+idle:2").unwrap();
//! assert_eq!(mix.k(), 2);
//! assert_eq!(mix.idle_cores, 2);
//! assert_eq!(mix.groups[0].remote_frac(), 0.25);
//! assert_eq!(mix.label(), "dcopy:8@d0%r0.25+ddot2:8@d1+idle:2");
//! assert_eq!(Mix::parse(&mix.label()).unwrap(), mix);
//! ```

pub mod cache;
mod results;
mod runner;
mod spec;

pub use cache::{CacheStats, CharCache, CharKey, CharSource, EngineKind};
pub use results::{
    GroupOutcome, LinkResult, MixResult, MixResultSet, ScenarioResult, TopoMixResult,
    TopoMixResultSet, TopoScenarioResult,
};
pub use runner::{run_mixes, run_mixes_on, run_scenario, run_scenario_on, MeasureEngine};
pub use spec::{remote_ppm_of, slugify, BoundHint, GroupSpec, Mix, Scenario};
