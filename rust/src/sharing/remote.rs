//! Remote-access extension of the sharing model: multi-socket and SNC
//! topologies where part of a group's cache-line stream leaves its home
//! ccNUMA domain.
//!
//! The paper's Eqs. (4)+(5) assume all traffic of a contention domain stays
//! on that domain's memory interface. Real multi-socket machines (and the
//! paper's own dual-socket testbed) violate this whenever data is placed
//! remotely: a line then contends on the *target* domain's memory interface
//! and, if the target sits on another socket, additionally on the
//! inter-socket link (QPI/UPI on Intel, xGMI on Rome).
//!
//! This module models that with three deliberate rules (all documented in
//! `docs/MODEL.md`):
//!
//! 1. **Uniform spread** — a group with remote fraction `r` keeps `1-r` of
//!    its stream on its home domain and spreads `r` uniformly over all
//!    other domains (the behaviour of interleaved/first-touch-miss pages).
//! 2. **Directed full-duplex links** — every socket pair contributes TWO
//!    link interfaces, one per direction; a cross-socket portion rides the
//!    directed link `socket(home) → socket(target)` (the direction its
//!    cores issue into), so opposing traffic no longer contends.
//! 3. **Lockstep streams with a global fixed point** — a core interleaves
//!    its local and remote lines in fixed proportion, so the slowest
//!    portion gates the whole stream: the per-core bandwidth of a group is
//!    `min_p grant_p / (n·w_p)` over its portions `p`. Every memory
//!    interface and every link evaluates the generalized water-fill over
//!    the traffic portions it carries ([`share_weighted_capped`] with
//!    fractional thread counts; links use their own directed capacity) —
//!    and the evaluation iterates to a fixed point: a gated group's
//!    demand is re-offered as only what its slowest portion can drain
//!    (`n·w·rate`), so the capacity its faster portions cannot use is
//!    redistributed to the other groups instead of being stranded. The
//!    uncapped first pass is returned verbatim when no group is gated,
//!    which keeps every degenerate case bit-identical to the historical
//!    single-pass evaluation.
//!
//! With `r = 0` everything collapses to one home portion of weight 1 and
//! the evaluation is bit-identical to [`share_domains`] (pinned by the
//! topology conformance suite).
//!
//! The measurement substrate simulates the *same* interface network with
//! the same portion expansion ([`crate::simulator::route_streams`] mirrors
//! the routing in [`share_remote`] one for one), so the model's water-fill
//! can be validated against simulated — not offered — link traffic; see
//! `docs/SIMULATORS.md`.
//!
//! [`share_domains`]: crate::sharing::share_domains
//!
//! # Examples
//!
//! ```
//! use membw::sharing::{share_remote, GroupKind, RemoteGroup, TopoShape};
//!
//! // Two sockets x one domain, 10 GB/s per link direction.
//! let shape = TopoShape {
//!     socket_of: vec![0, 1],
//!     bw_scale: vec![1.0, 1.0],
//!     link_bw_gbs: 10.0,
//!     link_bw_rev_gbs: 10.0,
//!     l3_bw_gbs: 0.0,
//! };
//! // 8 cores on domain 0 sending a quarter of their lines to domain 1.
//! let groups = [RemoteGroup {
//!     home: 0,
//!     n: 8,
//!     f: 0.3,
//!     bs_gbs: 60.0,
//!     remote_frac: 0.25,
//!     kind: GroupKind::Mem,
//! }];
//! let share = share_remote(&shape, &groups).unwrap();
//! // The remote quarter crosses the s0->s1 direction of the duplex link...
//! assert_eq!(shape.links(), vec![(0, 1), (1, 0)]);
//! assert!(share.links[0].demand_gbs > 0.0);
//! assert_eq!(share.links[1].demand_gbs, 0.0);
//! // ...and the group cannot beat its solo bandwidth.
//! assert!(share.per_core_gbs[0] <= 0.3 * 60.0 + 1e-9);
//! ```

use std::collections::HashMap;

use crate::error::{Error, Result};
use crate::sharing::multigroup::{share_weighted_capped, WeightedGroup};

/// The shape of a topology as the remote model sees it: which socket each
/// ccNUMA domain belongs to, the per-domain bandwidth scales, and the
/// per-direction saturated bandwidths of the inter-socket links.
#[derive(Debug, Clone, PartialEq)]
pub struct TopoShape {
    /// Socket of each domain, in domain order.
    pub socket_of: Vec<usize>,
    /// Saturated-bandwidth scale of each domain (1.0 = nominal).
    pub bw_scale: Vec<f64>,
    /// Saturated bandwidth of the forward direction (lower → higher socket
    /// index) of one inter-socket link, GB/s per socket pair (0 = links
    /// not modeled; remote traffic then only contends on the target
    /// domain's memory interface).
    pub link_bw_gbs: f64,
    /// Saturated bandwidth of the reverse direction (higher → lower socket
    /// index), GB/s. Equal to [`TopoShape::link_bw_gbs`] on symmetric
    /// duplex machines (the common case, and the loader default).
    pub link_bw_rev_gbs: f64,
    /// Aggregate bandwidth of one socket's shared-L3 cache, GB/s (0 = L3
    /// not modeled as a contention interface; L3-resident groups are then
    /// rejected). Each socket contributes one shared-L3 interface node,
    /// fixed-capacity like the links (the per-domain `bw_scale` does NOT
    /// apply — it models memory-side throttling).
    pub l3_bw_gbs: f64,
}

impl TopoShape {
    /// Number of ccNUMA domains.
    pub fn n_domains(&self) -> usize {
        self.socket_of.len()
    }

    /// Number of sockets.
    pub fn n_sockets(&self) -> usize {
        self.socket_of.iter().copied().max().map_or(0, |s| s + 1)
    }

    /// The inter-socket links: all DIRECTED socket pairs `(a, b)` with
    /// `a != b`, lexicographic. Each direction is its own contention
    /// interface ([`TopoShape::link_capacity_gbs`] gives its capacity).
    pub fn links(&self) -> Vec<(usize, usize)> {
        let s = self.n_sockets();
        let mut out = Vec::new();
        for a in 0..s {
            for b in 0..s {
                if a != b {
                    out.push((a, b));
                }
            }
        }
        out
    }

    /// Capacity of one directed link, GB/s: forward (`a < b`) directions
    /// saturate at [`TopoShape::link_bw_gbs`], reverse directions at
    /// [`TopoShape::link_bw_rev_gbs`].
    pub fn link_capacity_gbs(&self, link: (usize, usize)) -> f64 {
        if link.0 < link.1 {
            self.link_bw_gbs
        } else {
            self.link_bw_rev_gbs
        }
    }
}

/// The shared portion-routing rule of model and measurement: the slices
/// of one stream homed on `home` with remote fraction `remote_frac`, as
/// `(target domain, link index, weight)` triples — the home portion of
/// weight `1-r` first (omitted at `r = 1`), then `r/(D-1)` per remote
/// target in domain order, with the DIRECTED link
/// `socket(home) → socket(target)` attached when the target lives on
/// another socket and `links_modeled` is set.
///
/// [`share_remote`] expands its analytic groups through this function and
/// the simulation substrate routes its per-core streams through the very
/// same one (`route_streams` in `simulator::network`), so the two sides
/// cannot drift apart.
///
/// The caller validates inputs first: `remote_frac` must be in `[0, 1]`,
/// `home` in range, and `remote_frac > 0` needs at least two domains.
pub fn portion_routes(
    socket_of: &[usize],
    links: &[(usize, usize)],
    links_modeled: bool,
    home: usize,
    remote_frac: f64,
) -> Vec<(usize, Option<usize>, f64)> {
    let nd = socket_of.len();
    let mut out = Vec::new();
    let home_w = 1.0 - remote_frac;
    if home_w > 0.0 {
        out.push((home, None, home_w));
    }
    if remote_frac > 0.0 {
        let w = remote_frac / (nd - 1) as f64;
        for t in 0..nd {
            if t == home {
                continue;
            }
            let link = if socket_of[t] != socket_of[home] && links_modeled {
                let dir = (socket_of[home], socket_of[t]);
                links.iter().position(|&l| l == dir)
            } else {
                None
            };
            out.push((t, link, w));
        }
    }
    out
}

/// Where a group's working set is bound — which shared interfaces its
/// line stream actually contends on.
///
/// The default ([`GroupKind::Mem`]) is the paper's assumption: every
/// kernel is DRAM-bound and the memory controllers (plus links) are the
/// only shared resources. The two other kinds wire the in-tree cache
/// topology layers (`kernels::layer_condition`, `ecm::application`,
/// `ecm::scaling`) into the sharing network; see `docs/MODEL.md`.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum GroupKind {
    /// DRAM-bound: all portions contend on memory interfaces (and links).
    Mem,
    /// L3-resident (the working set hits in L2/L3, e.g. a stencil whose
    /// layer condition holds at L3): ALL its L2-miss lines contend on the
    /// home socket's shared-L3 interface with the L3-level
    /// characterization below, and its DRAM continuation (`f · bs_gbs`,
    /// when nonzero) contends on the home memory interface in tandem —
    /// the slower stage gates the stream. Its per-core rates are reported
    /// at the L3 (L2-miss) level.
    L3 {
        /// L2↔L3 transfer-time fraction of the kernel, `t_L2L3 / t_ECM`.
        f_l3: f64,
        /// Per-core saturated L2↔L3 bandwidth, GB/s (`l2l3_bpc · freq`).
        bs_l3_gbs: f64,
    },
    /// Compute-bound (left of the roofline knee, `n · f < 1`): runs at its
    /// core-bound rate `f · bs_gbs` and consumes zero bandwidth share on
    /// every interface.
    Compute,
}

impl Default for GroupKind {
    fn default() -> Self {
        GroupKind::Mem
    }
}

/// One kernel group resident on a home domain, with a remote-access split.
#[derive(Debug, Clone, Copy)]
pub struct RemoteGroup {
    /// Home domain (where the group's cores are pinned).
    pub home: usize,
    /// Cores in the group.
    pub n: usize,
    /// Memory request fraction of the kernel (Eq. 2).
    pub f: f64,
    /// Nominal (unscaled) saturated bandwidth of the kernel, GB/s; the
    /// per-domain scale of the *target* domain is applied per portion.
    pub bs_gbs: f64,
    /// Fraction of the group's cache-line stream that goes to remote
    /// domains (uniformly spread); in `[0, 1]`.
    pub remote_frac: f64,
    /// Which shared interfaces the group contends on (see [`GroupKind`]).
    pub kind: GroupKind,
}

/// One traffic portion of a group: the slice of its line stream aimed at
/// one target domain (and possibly crossing one inter-socket link).
#[derive(Debug, Clone, Copy)]
pub struct Portion {
    /// Index of the group in the input slice.
    pub group: usize,
    /// Target domain of the portion.
    pub target: usize,
    /// Fraction of the group's stream in this portion.
    pub weight: f64,
    /// Index into [`TopoShape::links`] if the portion crosses sockets
    /// (None when intra-socket or when links are not modeled).
    pub link: Option<usize>,
    /// Socket whose shared-L3 interface this portion contends on (only
    /// the L3 portion of an [`GroupKind::L3`] group; None otherwise).
    pub l3: Option<usize>,
    /// Whether the portion queues on its target memory interface. True
    /// for every portion of a memory-bound group and for the DRAM
    /// continuation of an L3 group; false for an L3-only portion.
    pub mem: bool,
    /// Conversion from the group's reporting unit to this portion's
    /// interface unit: a group's per-core rate cap is multiplied by this
    /// before capping the portion's demand. 1.0 everywhere except the
    /// DRAM continuation of an L3 group, where it is
    /// `(f·bs) / (f_l3·bs_l3)` (DRAM GB/s per L3-level GB/s).
    pub cap_scale: f64,
    /// Water-fill grant on the target memory interface, GB/s.
    pub mem_bw_gbs: f64,
    /// Water-fill grant on the link (only meaningful when `link` is set).
    pub link_grant_gbs: f64,
    /// Water-fill grant on the shared-L3 interface (only meaningful when
    /// `l3` is set).
    pub l3_grant_gbs: f64,
    /// Effective grant at the portion's own interface(s), GB/s.
    pub granted_bw_gbs: f64,
}

/// Summary of one contention interface (a domain's memory interface or an
/// inter-socket link).
#[derive(Debug, Clone, Copy, Default)]
pub struct InterfaceShare {
    /// Capacity of the interface under its traffic mix, GB/s (generalized
    /// Eq. 4 for memory interfaces; `link_bw` for links).
    pub b_mix_gbs: f64,
    /// Total unconstrained demand offered to the interface, GB/s.
    pub demand_gbs: f64,
    /// Whether demand meets or exceeds capacity.
    pub saturated: bool,
}

/// Result of the remote-aware sharing evaluation.
#[derive(Debug, Clone)]
pub struct RemoteShare {
    /// Per-core bandwidth of each input group after the lockstep-stream
    /// bottleneck, GB/s.
    pub per_core_gbs: Vec<f64>,
    /// Aggregate bandwidth of each input group (`n ·` per-core), GB/s.
    pub group_bw_gbs: Vec<f64>,
    /// Per-domain memory-interface summaries.
    pub domains: Vec<InterfaceShare>,
    /// Per-link summaries, parallel to [`TopoShape::links`].
    pub links: Vec<InterfaceShare>,
    /// Per-socket shared-L3 interface summaries (empty when
    /// [`TopoShape::l3_bw_gbs`] is 0, i.e. L3 not modeled).
    pub l3: Vec<InterfaceShare>,
    /// All traffic portions with their grants (reporting detail).
    pub portions: Vec<Portion>,
    /// Water-fill passes until convergence: 1 when no group was gated (the
    /// uncapped pass is already the fixed point), > 1 otherwise.
    pub iterations: usize,
    /// Whether the fixed point actually converged (cap movement below
    /// [`FIXED_POINT_TOL`]). `false` means the Gauss-Seidel iteration ran
    /// into its sweep cap and the result is the last iterate — callers
    /// surfacing model numbers should report that.
    pub converged: bool,
}

/// Sweep cap of the fixed-point iteration. In practice gated scenarios
/// converge in a handful of sweeps (the stranded-capacity regression takes
/// 3); the cap only bounds pathological non-convergence.
const MAX_FIXED_POINT_SWEEPS: usize = 64;

/// Relative convergence tolerance on the per-group rate caps.
const FIXED_POINT_TOL: f64 = 1e-12;

/// Relative slack when deciding whether a portion outruns its group's
/// lockstep rate (i.e. whether the group is gated at all); loose enough to
/// ignore round-off between portions of an ungated group.
///
/// `pub(crate)` so the optimizer's delta evaluator
/// ([`crate::optimizer::DeltaEval`]) applies the *same* gating test and
/// stays bit-identical to this module.
pub(crate) const GATING_TOL: f64 = 1e-9;

/// One global water-fill over every interface with per-group per-core rate
/// caps: grants per portion plus per-interface summaries.
struct Fill {
    mem_grant: Vec<f64>,
    link_grant: Vec<f64>,
    l3_grant: Vec<f64>,
    domains: Vec<InterfaceShare>,
    links: Vec<InterfaceShare>,
    l3: Vec<InterfaceShare>,
}

/// Expand `groups` into traffic portions, validating homes and fractions.
/// The single portion-expansion path of the model — [`share_remote`] and
/// the optimizer's delta evaluator both call it, so a candidate placement
/// and its full re-solve can never route differently.
pub(crate) fn expand_portions(
    shape: &TopoShape,
    groups: &[RemoteGroup],
    links: &[(usize, usize)],
) -> Result<Vec<Portion>> {
    let nd = shape.n_domains();
    let mut portions: Vec<Portion> = Vec::new();
    for (gi, g) in groups.iter().enumerate() {
        if !g.remote_frac.is_finite() || !(0.0..=1.0).contains(&g.remote_frac) {
            return Err(Error::InvalidPlan(format!(
                "remote fraction {} of group {gi} outside [0, 1]",
                g.remote_frac
            )));
        }
        if g.home >= nd {
            return Err(Error::InvalidPlan(format!(
                "group {gi} homed on domain d{} but the shape has {nd} domains",
                g.home
            )));
        }
        if g.remote_frac > 0.0 && nd < 2 {
            return Err(Error::InvalidPlan(
                "remote accesses need at least two ccNUMA domains".into(),
            ));
        }
        match g.kind {
            // Compute-bound groups never queue on a shared interface.
            GroupKind::Compute => continue,
            GroupKind::L3 { f_l3, bs_l3_gbs } => {
                if shape.l3_bw_gbs <= 0.0 {
                    return Err(Error::InvalidPlan(format!(
                        "group {gi} is L3-resident but the machine models no \
                         shared-L3 bandwidth (l3_bw_gbs = 0)"
                    )));
                }
                if g.remote_frac > 0.0 {
                    return Err(Error::InvalidPlan(format!(
                        "group {gi} is L3-resident and cannot spread remotely \
                         (remote_frac {})",
                        g.remote_frac
                    )));
                }
                if !(f_l3 > 0.0) || !(bs_l3_gbs > 0.0) {
                    return Err(Error::InvalidPlan(format!(
                        "group {gi} has a non-positive L3 characterization \
                         (f_l3 {f_l3}, bs_l3 {bs_l3_gbs})"
                    )));
                }
                // ALL L2-miss lines contend on the home socket's L3 node...
                let sock = shape.socket_of[g.home];
                portions.push(Portion {
                    group: gi,
                    target: g.home,
                    weight: 1.0,
                    link: None,
                    l3: Some(sock),
                    mem: false,
                    cap_scale: 1.0,
                    mem_bw_gbs: 0.0,
                    link_grant_gbs: 0.0,
                    l3_grant_gbs: 0.0,
                    granted_bw_gbs: 0.0,
                });
                // ...and the DRAM continuation (if any) on the home memory
                // interface, in tandem: both portions carry weight 1.0 and
                // the lockstep min over them gates the stream. cap_scale
                // converts the group's L3-level rate cap to DRAM units.
                if g.f * g.bs_gbs > 0.0 {
                    portions.push(Portion {
                        group: gi,
                        target: g.home,
                        weight: 1.0,
                        link: None,
                        l3: None,
                        mem: true,
                        cap_scale: (g.f * g.bs_gbs) / (f_l3 * bs_l3_gbs),
                        mem_bw_gbs: 0.0,
                        link_grant_gbs: 0.0,
                        l3_grant_gbs: 0.0,
                        granted_bw_gbs: 0.0,
                    });
                }
                continue;
            }
            GroupKind::Mem => {}
        }
        for (target, link, weight) in
            portion_routes(&shape.socket_of, links, shape.link_bw_gbs > 0.0, g.home, g.remote_frac)
        {
            portions.push(Portion {
                group: gi,
                target,
                weight,
                link,
                l3: None,
                mem: true,
                cap_scale: 1.0,
                mem_bw_gbs: 0.0,
                link_grant_gbs: 0.0,
                l3_grant_gbs: 0.0,
                granted_bw_gbs: 0.0,
            });
        }
    }
    Ok(portions)
}

/// Water-fill one domain's memory interface over the portions `idx` (all
/// with `target == d`, in global portion-index order), writing grants into
/// `mem_grant` at the global indices. The capacity (generalized Eq. 4
/// mean) is taken over the *uncapped* thread weights, so caps redistribute
/// bandwidth without changing what the interface can deliver.
///
/// `pub(crate)`: this is the per-interface unit the optimizer's delta
/// evaluator re-runs on dirty interfaces only — the shared implementation
/// is what makes delta evaluation bit-identical to [`share_remote`].
pub(crate) fn fill_mem_iface(
    shape: &TopoShape,
    groups: &[RemoteGroup],
    portions: &[Portion],
    idx: &[usize],
    d: usize,
    caps: &[f64],
    mem_grant: &mut [f64],
) -> InterfaceShare {
    let wg: Vec<WeightedGroup> = idx
        .iter()
        .map(|&p| {
            let g = &groups[portions[p].group];
            WeightedGroup {
                n: g.n as f64 * portions[p].weight,
                f: g.f,
                bs_gbs: g.bs_gbs * shape.bw_scale[d],
            }
        })
        .collect();
    let n_tot: f64 = wg.iter().map(|g| g.n).sum();
    if n_tot == 0.0 {
        return InterfaceShare::default();
    }
    let b_mix: f64 = wg.iter().map(|g| g.n * g.bs_gbs).sum::<f64>() / n_tot;
    let rc: Vec<f64> =
        idx.iter().map(|&p| caps[portions[p].group] * portions[p].cap_scale).collect();
    let share = share_weighted_capped(&wg, b_mix, &rc);
    for (k, &p) in idx.iter().enumerate() {
        mem_grant[p] = share.groups[k].group_bw_gbs;
    }
    InterfaceShare {
        b_mix_gbs: b_mix,
        demand_gbs: wg.iter().map(|g| g.n * g.f * g.bs_gbs).sum(),
        saturated: share.saturated,
    }
}

/// Water-fill one directed link over the portions `idx` (all with
/// `link == Some(li)`, in global portion-index order) at its own
/// per-direction capacity; a portion's demand is still that of the memory
/// stream it ships. Shared with the delta evaluator like
/// [`fill_mem_iface`].
pub(crate) fn fill_link_iface(
    shape: &TopoShape,
    groups: &[RemoteGroup],
    portions: &[Portion],
    idx: &[usize],
    li: usize,
    links: &[(usize, usize)],
    caps: &[f64],
    link_grant: &mut [f64],
) -> InterfaceShare {
    if idx.is_empty() {
        return InterfaceShare::default();
    }
    let wg: Vec<WeightedGroup> = idx
        .iter()
        .map(|&p| {
            let g = &groups[portions[p].group];
            WeightedGroup {
                n: g.n as f64 * portions[p].weight,
                f: g.f,
                bs_gbs: g.bs_gbs * shape.bw_scale[portions[p].target],
            }
        })
        .collect();
    let capacity = shape.link_capacity_gbs(links[li]);
    let rc: Vec<f64> =
        idx.iter().map(|&p| caps[portions[p].group] * portions[p].cap_scale).collect();
    let share = share_weighted_capped(&wg, capacity, &rc);
    for (k, &p) in idx.iter().enumerate() {
        link_grant[p] = share.groups[k].group_bw_gbs;
    }
    InterfaceShare {
        b_mix_gbs: capacity,
        demand_gbs: wg.iter().map(|g| g.n * g.f * g.bs_gbs).sum(),
        saturated: share.saturated,
    }
}

/// Water-fill one socket's shared-L3 interface over the portions `idx`
/// (all with `l3 == Some(s)`, in global portion-index order) at the
/// fixed capacity [`TopoShape::l3_bw_gbs`]. An L3 portion's
/// characterization is its group's L3-level `(f_l3, bs_l3)` pair, not its
/// DRAM chars — the L3 node shares L2-miss bandwidth, not DRAM bandwidth.
/// Shared with the delta evaluator like [`fill_mem_iface`].
pub(crate) fn fill_l3_iface(
    shape: &TopoShape,
    groups: &[RemoteGroup],
    portions: &[Portion],
    idx: &[usize],
    caps: &[f64],
    l3_grant: &mut [f64],
) -> InterfaceShare {
    if idx.is_empty() {
        return InterfaceShare::default();
    }
    let wg: Vec<WeightedGroup> = idx
        .iter()
        .map(|&p| {
            let g = &groups[portions[p].group];
            let (f_l3, bs_l3) = match g.kind {
                GroupKind::L3 { f_l3, bs_l3_gbs } => (f_l3, bs_l3_gbs),
                // expand_portions only routes L3 portions for L3 groups.
                _ => unreachable!("L3 portion of a non-L3 group"),
            };
            WeightedGroup { n: g.n as f64 * portions[p].weight, f: f_l3, bs_gbs: bs_l3 }
        })
        .collect();
    let rc: Vec<f64> =
        idx.iter().map(|&p| caps[portions[p].group] * portions[p].cap_scale).collect();
    let share = share_weighted_capped(&wg, shape.l3_bw_gbs, &rc);
    for (k, &p) in idx.iter().enumerate() {
        l3_grant[p] = share.groups[k].group_bw_gbs;
    }
    InterfaceShare {
        b_mix_gbs: shape.l3_bw_gbs,
        demand_gbs: wg.iter().map(|g| g.n * g.f * g.bs_gbs).sum(),
        saturated: share.saturated,
    }
}

fn fill(
    shape: &TopoShape,
    groups: &[RemoteGroup],
    portions: &[Portion],
    links: &[(usize, usize)],
    caps: &[f64],
) -> Fill {
    let nd = shape.n_domains();
    let mut mem_grant = vec![0.0f64; portions.len()];
    let mut link_grant = vec![0.0f64; portions.len()];
    let mut l3_grant = vec![0.0f64; portions.len()];

    let mut domains = vec![InterfaceShare::default(); nd];
    for (d, dom_share) in domains.iter_mut().enumerate() {
        let idx: Vec<usize> =
            (0..portions.len()).filter(|&p| portions[p].target == d && portions[p].mem).collect();
        *dom_share = fill_mem_iface(shape, groups, portions, &idx, d, caps, &mut mem_grant);
    }

    let mut link_shares = vec![InterfaceShare::default(); links.len()];
    for (li, link_share) in link_shares.iter_mut().enumerate() {
        let idx: Vec<usize> =
            (0..portions.len()).filter(|&p| portions[p].link == Some(li)).collect();
        *link_share =
            fill_link_iface(shape, groups, portions, &idx, li, links, caps, &mut link_grant);
    }

    let n_l3 = if shape.l3_bw_gbs > 0.0 { shape.n_sockets() } else { 0 };
    let mut l3_shares = vec![InterfaceShare::default(); n_l3];
    for (s, l3_share) in l3_shares.iter_mut().enumerate() {
        let idx: Vec<usize> = (0..portions.len()).filter(|&p| portions[p].l3 == Some(s)).collect();
        *l3_share = fill_l3_iface(shape, groups, portions, &idx, caps, &mut l3_grant);
    }

    Fill { mem_grant, link_grant, l3_grant, domains, links: link_shares, l3: l3_shares }
}

/// The grant of portion `i` at its own interface(s): the L3 grant for an
/// L3-only portion, the mem/link minimum for a cross-socket portion, the
/// mem grant otherwise. One helper so [`lockstep_rate`], [`any_gated`]
/// and the final reporting pass cannot disagree.
pub(crate) fn portion_grant(
    p: &Portion,
    i: usize,
    mem_grant: &[f64],
    link_grant: &[f64],
    l3_grant: &[f64],
) -> f64 {
    if p.l3.is_some() && !p.mem {
        l3_grant[i]
    } else {
        match p.link {
            Some(_) => mem_grant[i].min(link_grant[i]),
            None => mem_grant[i],
        }
    }
}

/// Lockstep rate of one group under a fill:
/// `min_p grant_p / (n · w_p) / cap_scale_p` over its portions, reported
/// in the group's own unit — DRAM GB/s for memory-bound groups, L3-level
/// (L2-miss) GB/s for L3 groups. A cross-socket portion is gated by the
/// slower of its two interfaces; an L3 group by the slower of its L3 node
/// and DRAM-continuation stages. Compute-bound groups have no portions
/// and run at their core-bound rate `f · bs`. Takes raw grant slices so
/// the optimizer's delta evaluator shares the exact arithmetic.
pub(crate) fn lockstep_rate(
    groups: &[RemoteGroup],
    portions: &[Portion],
    mem_grant: &[f64],
    link_grant: &[f64],
    l3_grant: &[f64],
    gi: usize,
) -> f64 {
    if let GroupKind::Compute = groups[gi].kind {
        return groups[gi].f * groups[gi].bs_gbs;
    }
    let n = groups[gi].n as f64;
    if n == 0.0 {
        return 0.0;
    }
    let mut rate = f64::INFINITY;
    for (i, p) in portions.iter().enumerate() {
        if p.group != gi {
            continue;
        }
        let grant = portion_grant(p, i, mem_grant, link_grant, l3_grant);
        rate = rate.min(grant / (n * p.weight) / p.cap_scale);
    }
    if rate.is_finite() {
        rate
    } else {
        0.0
    }
}

fn group_rate(groups: &[RemoteGroup], portions: &[Portion], f: &Fill, gi: usize) -> f64 {
    lockstep_rate(groups, portions, &f.mem_grant, &f.link_grant, &f.l3_grant, gi)
}

/// Whether any group is gated by a slower portion under the pass-1 fill —
/// the trigger of the Gauss-Seidel sweeps. Shared with the delta evaluator
/// (which falls back to the full solve whenever this fires).
pub(crate) fn any_gated(
    groups: &[RemoteGroup],
    portions: &[Portion],
    mem_grant: &[f64],
    link_grant: &[f64],
    l3_grant: &[f64],
    rates: &[f64],
) -> bool {
    for (i, p) in portions.iter().enumerate() {
        let n = groups[p.group].n as f64;
        if n == 0.0 {
            continue;
        }
        let grant = portion_grant(p, i, mem_grant, link_grant, l3_grant);
        if grant / (n * p.weight) / p.cap_scale > rates[p.group] * (1.0 + GATING_TOL) {
            return true;
        }
    }
    false
}

/// Evaluate the remote-aware sharing model over `groups` on `shape`.
///
/// The evaluation is a global fixed point over the whole interface
/// network. Pass 1 is the plain uncapped water-fill on every interface; if
/// no group is gated by a slower portion it is returned verbatim
/// (`iterations == 1`, bit-identical to the historical single-pass
/// evaluation). Otherwise Gauss-Seidel sweeps re-evaluate each group
/// *uncapped* against the others capped at their current lockstep rates,
/// so the capacity a gated group's faster portions cannot drain is
/// redistributed to the other groups instead of being stranded; sweeps
/// stop when no cap moves by more than [`FIXED_POINT_TOL`] (relative) or
/// after [`MAX_FIXED_POINT_SWEEPS`].
///
/// Fails when a remote fraction is outside `[0, 1]`, when a group with
/// remote traffic sits on a single-domain shape, or when a home domain is
/// out of range.
pub fn share_remote(shape: &TopoShape, groups: &[RemoteGroup]) -> Result<RemoteShare> {
    share_remote_with_cap(shape, groups, MAX_FIXED_POINT_SWEEPS)
}

/// [`share_remote`] with an explicit sweep cap — test hook for forcing the
/// non-converged-at-cap path (`RemoteShare::converged == false`).
#[doc(hidden)]
pub fn share_remote_with_cap(
    shape: &TopoShape,
    groups: &[RemoteGroup],
    max_sweeps: usize,
) -> Result<RemoteShare> {
    let links = shape.links();

    // 1. Expand groups into traffic portions (validates homes/fractions).
    let mut portions = expand_portions(shape, groups, &links)?;

    // 2. Pass 1: uncapped global fill (the historical single-pass answer).
    let k = groups.len();
    let mut caps = vec![f64::INFINITY; k];
    let first = fill(shape, groups, &portions, &links, &caps);
    let rates: Vec<f64> = (0..k).map(|g| group_rate(groups, &portions, &first, g)).collect();

    // 3. A group is gated when some portion of it could run faster than
    // its lockstep rate — that surplus grant is stranded capacity.
    let gated =
        any_gated(groups, &portions, &first.mem_grant, &first.link_grant, &first.l3_grant, &rates);

    let (per_core_gbs, final_fill, iterations, converged) = if !gated {
        // No stranded capacity: pass 1 is already the fixed point.
        (rates, first, 1, true)
    } else {
        // 4. Gauss-Seidel sweeps: re-fill with group g uncapped and every
        // other group capped at its current rate; g's resulting lockstep
        // rate becomes its new cap. Converged when no cap moves.
        let mut iterations = 1usize;
        let mut converged = false;
        for _ in 0..max_sweeps {
            let mut delta =
                if caps.iter().any(|c| !c.is_finite()) { f64::INFINITY } else { 0.0 };
            for g in 0..k {
                let saved = caps[g];
                caps[g] = f64::INFINITY;
                let f = fill(shape, groups, &portions, &links, &caps);
                let r = group_rate(groups, &portions, &f, g);
                caps[g] = r;
                if saved.is_finite() {
                    delta = delta.max((r - saved).abs() / saved.max(1.0));
                }
            }
            iterations += 1;
            if delta <= FIXED_POINT_TOL {
                converged = true;
                break;
            }
        }
        // Reporting fill with every group at its converged cap.
        let f = fill(shape, groups, &portions, &links, &caps);
        (caps, f, iterations, converged)
    };

    for (i, p) in portions.iter_mut().enumerate() {
        p.mem_bw_gbs = final_fill.mem_grant[i];
        p.link_grant_gbs = final_fill.link_grant[i];
        p.l3_grant_gbs = final_fill.l3_grant[i];
        p.granted_bw_gbs = portion_grant(
            p,
            i,
            &final_fill.mem_grant,
            &final_fill.link_grant,
            &final_fill.l3_grant,
        );
    }
    let group_bw_gbs: Vec<f64> =
        per_core_gbs.iter().zip(groups).map(|(&r, g)| r * g.n as f64).collect();

    Ok(RemoteShare {
        per_core_gbs,
        group_bw_gbs,
        domains: final_fill.domains,
        links: final_fill.links,
        l3: final_fill.l3,
        portions,
        iterations,
        converged,
    })
}

/// Upper bound on memoized compositions in a [`RemoteRateModel`]: far
/// above what a co-sim revisits (hundreds), low enough that the map can
/// never grow with simulated time.
const MAX_CACHED_COMPOSITIONS: usize = 4096;

/// Memoized remote-aware rate evaluation for the contention-timeline
/// engine: a global composition (core counts per `(domain, kernel)` slot)
/// maps to per-slot per-core drain rates in bytes/s.
///
/// Unlike the per-domain [`crate::sharing::ShareCache`], remote traffic
/// couples every domain (and the links), so the whole composition is one
/// cache key and one [`share_remote`] evaluation.
pub struct RemoteRateModel {
    shape: TopoShape,
    /// Remote fraction per home domain.
    frac: Vec<f64>,
    /// `(f, b_s[GB/s])` per kernel slot (nominal, unscaled).
    chars: Vec<(f64, f64)>,
    /// Cache-topology kind per kernel slot ([`GroupKind::Mem`] unless the
    /// caller classified the slot otherwise).
    kinds: Vec<GroupKind>,
    cache: HashMap<Vec<u16>, Vec<f64>>,
    hits: u64,
    misses: u64,
}

impl RemoteRateModel {
    /// Build a model for `shape` with per-domain remote fractions `frac`
    /// and per-slot kernel characterizations `chars` (`(f, b_s)` in slot
    /// order).
    ///
    /// # Panics
    /// If `frac` does not cover every domain, a fraction is outside
    /// `[0, 1]`, or remote traffic is requested on a single-domain shape —
    /// all programming errors of the caller (the layout is validated at
    /// construction time in [`crate::topology::RankLayout::with_remote`]).
    pub fn new(shape: TopoShape, frac: Vec<f64>, chars: Vec<(f64, f64)>) -> Self {
        let kinds = vec![GroupKind::Mem; chars.len()];
        Self::new_with_kinds(shape, frac, chars, kinds)
    }

    /// [`RemoteRateModel::new`] with an explicit cache-topology kind per
    /// kernel slot. An [`GroupKind::L3`] slot must only be populated on
    /// domains with remote fraction 0 (L3-resident streams do not spread),
    /// and needs [`TopoShape::l3_bw_gbs`] > 0 — both are enforced per
    /// composition by [`share_remote`].
    pub fn new_with_kinds(
        shape: TopoShape,
        frac: Vec<f64>,
        chars: Vec<(f64, f64)>,
        kinds: Vec<GroupKind>,
    ) -> Self {
        assert_eq!(frac.len(), shape.n_domains(), "one remote fraction per domain");
        assert_eq!(kinds.len(), chars.len(), "one kind per kernel slot");
        for &r in &frac {
            assert!(
                r.is_finite() && (0.0..=1.0).contains(&r),
                "remote fraction {r} outside [0, 1]"
            );
        }
        assert!(
            shape.n_domains() >= 2 || frac.iter().all(|&r| r == 0.0),
            "remote accesses need at least two ccNUMA domains"
        );
        RemoteRateModel { shape, frac, chars, kinds, cache: HashMap::new(), hits: 0, misses: 0 }
    }

    /// Number of kernel slots.
    pub fn slots(&self) -> usize {
        self.chars.len()
    }

    /// One uncached evaluation of the global composition `counts`.
    fn compute(
        shape: &TopoShape,
        frac: &[f64],
        chars: &[(f64, f64)],
        kinds: &[GroupKind],
        counts: &[u16],
    ) -> Vec<f64> {
        let nk = chars.len();
        let mut slots: Vec<usize> = Vec::new();
        let mut groups: Vec<RemoteGroup> = Vec::new();
        for d in 0..shape.n_domains() {
            for (k, &(f, bs)) in chars.iter().enumerate() {
                let c = counts[d * nk + k];
                if c > 0 {
                    slots.push(d * nk + k);
                    groups.push(RemoteGroup {
                        home: d,
                        n: c as usize,
                        f,
                        bs_gbs: bs,
                        remote_frac: frac[d],
                        kind: kinds[k],
                    });
                }
            }
        }
        let mut rates = vec![0.0f64; counts.len()];
        if !groups.is_empty() {
            let share = share_remote(shape, &groups)
                .expect("shape and fractions validated at construction");
            for (i, &slot) in slots.iter().enumerate() {
                rates[slot] = share.per_core_gbs[i] * 1e9;
            }
        }
        rates
    }

    /// Per-core drain rates (bytes/s) per `(domain, kernel)` slot for the
    /// global composition `counts[d * slots + k]`. Memoized.
    // Not the entry API: that would allocate the `Vec<u16>` key on every
    // call, while `contains_key`/`get` borrow the slice directly — the hit
    // path (the timeline engine's per-event cadence) stays allocation-free.
    #[allow(clippy::map_entry)]
    pub fn rates_bytes(&mut self, counts: &[u16]) -> &[f64] {
        debug_assert_eq!(counts.len(), self.shape.n_domains() * self.chars.len());
        if self.cache.contains_key(counts) {
            self.hits += 1;
        } else {
            self.misses += 1;
            // Bound the memo: a long noisy co-sim churns compositions, and
            // unlike the 2-entry-MRU ShareCache this map would otherwise
            // grow with simulated time. A wholesale reset is cheap and
            // keeps results deterministic (entries are pure functions).
            if self.cache.len() >= MAX_CACHED_COMPOSITIONS {
                self.cache.clear();
            }
            let rates = Self::compute(&self.shape, &self.frac, &self.chars, &self.kinds, counts);
            self.cache.insert(counts.to_vec(), rates);
        }
        self.cache.get(counts).expect("present or just inserted").as_slice()
    }

    /// `(hits, misses, entries)` counter snapshot.
    pub fn stats(&self) -> (u64, u64, usize) {
        (self.hits, self.misses, self.cache.len())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sharing::multigroup::share_weighted_capacity;
    use crate::sharing::{share_multigroup, KernelGroup};

    /// The stranded-capacity regression (mirror-checked in
    /// `python/netfluid_mirror.py::check_stranded_capacity`): two groups on
    /// one home domain, one link-gated. The historical single pass left the
    /// gated group's unused memory grant stranded and under-predicted the
    /// ungated group (16/3 ≈ 5.33 GB/s/core); the fixed point redistributes
    /// it (7.5 GB/s/core).
    #[test]
    fn stranded_capacity_is_redistributed() {
        let shape = TopoShape {
            socket_of: vec![0, 1],
            bw_scale: vec![1.0, 1.0],
            link_bw_gbs: 2.0,
            link_bw_rev_gbs: 2.0,
            l3_bw_gbs: 0.0,
        };
        let groups = [
            RemoteGroup { home: 0, n: 4, f: 0.8, bs_gbs: 32.0, remote_frac: 0.5, kind: GroupKind::Mem },
            RemoteGroup { home: 0, n: 4, f: 0.8, bs_gbs: 32.0, remote_frac: 0.0, kind: GroupKind::Mem },
        ];
        let share = share_remote(&shape, &groups).unwrap();
        // A is gated by the 2 GB/s link: 2 / (4 * 0.5) = 1 GB/s per core.
        assert!((share.per_core_gbs[0] - 1.0).abs() < 1e-12, "{}", share.per_core_gbs[0]);
        // B gets everything A's home portion cannot drain: b_mix = 32,
        // A's home portion drains 4*0.5*1 = 2, so B = 30/4 = 7.5.
        assert!((share.per_core_gbs[1] - 7.5).abs() < 1e-12, "{}", share.per_core_gbs[1]);
        assert!(share.iterations > 1, "gated case must iterate");

        // The historical single pass (domain 0 water-fill over A's home
        // portion and B, no cap feedback) awards B only 16/3.
        let old = share_weighted_capacity(
            &[
                WeightedGroup { n: 2.0, f: 0.8, bs_gbs: 32.0 },
                WeightedGroup { n: 4.0, f: 0.8, bs_gbs: 32.0 },
            ],
            32.0,
        );
        let old_b = old.groups[1].group_bw_gbs / 4.0;
        assert!((old_b - 16.0 / 3.0).abs() < 1e-12, "{old_b}");
        assert!(share.per_core_gbs[1] > old_b + 2.0, "fixed point must beat the stranded answer");
        assert!(share.converged, "default sweep cap must suffice for this shape");
    }

    /// With the sweep cap forced to one, the gated fixed point cannot reach
    /// its tolerance and the result must say so instead of silently
    /// returning a partially relaxed answer.
    #[test]
    fn sweep_cap_exhaustion_is_reported() {
        let shape = TopoShape {
            socket_of: vec![0, 1],
            bw_scale: vec![1.0, 1.0],
            link_bw_gbs: 2.0,
            link_bw_rev_gbs: 2.0,
            l3_bw_gbs: 0.0,
        };
        let groups = [
            RemoteGroup { home: 0, n: 4, f: 0.8, bs_gbs: 32.0, remote_frac: 0.5, kind: GroupKind::Mem },
            RemoteGroup { home: 0, n: 4, f: 0.8, bs_gbs: 32.0, remote_frac: 0.0, kind: GroupKind::Mem },
        ];
        let capped = share_remote_with_cap(&shape, &groups, 1).unwrap();
        assert!(!capped.converged, "one sweep from infinite caps cannot settle");
        assert_eq!(capped.iterations, 2, "pass 1 plus the single allowed sweep");
        // The ungated branch never sweeps, so a cap of zero still converges.
        let ungated = [RemoteGroup { home: 0, n: 4, f: 0.8, bs_gbs: 32.0, remote_frac: 1.0, kind: GroupKind::Mem }];
        let one_pass = share_remote_with_cap(&shape, &ungated, 0).unwrap();
        assert!(one_pass.converged);
        assert_eq!(one_pass.iterations, 1);
    }

    /// Opposing cross-socket streams ride different directed interfaces of
    /// a full-duplex link and no longer contend: each gets the full
    /// per-direction capacity (the old shared-capacity model halved it).
    #[test]
    fn opposing_streams_use_both_link_directions() {
        let shape = TopoShape {
            socket_of: vec![0, 1],
            bw_scale: vec![1.0, 1.0],
            link_bw_gbs: 2.0,
            link_bw_rev_gbs: 2.0,
            l3_bw_gbs: 0.0,
        };
        let groups = [
            RemoteGroup { home: 0, n: 4, f: 0.8, bs_gbs: 32.0, remote_frac: 1.0, kind: GroupKind::Mem },
            RemoteGroup { home: 1, n: 4, f: 0.8, bs_gbs: 32.0, remote_frac: 1.0, kind: GroupKind::Mem },
        ];
        let share = share_remote(&shape, &groups).unwrap();
        // Single-portion groups are never gated: one pass.
        assert_eq!(share.iterations, 1);
        for pc in &share.per_core_gbs {
            assert!((pc - 0.5).abs() < 1e-12, "each direction delivers 2/4 GB/s/core, got {pc}");
        }
        assert!(share.links[0].saturated && share.links[1].saturated);
        assert!(share.links[0].demand_gbs > 0.0 && share.links[1].demand_gbs > 0.0);
    }

    fn two_socket_shape(link_bw: f64) -> TopoShape {
        TopoShape {
            socket_of: vec![0, 0, 1, 1],
            bw_scale: vec![1.0; 4],
            link_bw_gbs: link_bw,
            link_bw_rev_gbs: link_bw,
            l3_bw_gbs: 0.0,
        }
    }

    #[test]
    fn shape_links_enumerate_directed_socket_pairs() {
        assert_eq!(two_socket_shape(10.0).links(), vec![(0, 1), (1, 0)]);
        let four = TopoShape {
            socket_of: vec![0, 1, 2, 3],
            bw_scale: vec![1.0; 4],
            link_bw_gbs: 1.0,
            link_bw_rev_gbs: 2.0,
            l3_bw_gbs: 0.0,
        };
        let links = four.links();
        assert_eq!(links.len(), 12, "4 sockets -> 12 directed pairs");
        assert_eq!(links[0], (0, 1));
        assert_eq!(links[11], (3, 2));
        assert!(links.iter().all(|&(a, b)| a != b));
        assert_eq!(four.n_sockets(), 4);
        // Forward directions at link_bw, reverse at link_bw_rev.
        assert_eq!(four.link_capacity_gbs((0, 3)), 1.0);
        assert_eq!(four.link_capacity_gbs((3, 0)), 2.0);
    }

    /// r = 0 collapses to the per-domain evaluation, bit for bit.
    #[test]
    fn zero_remote_fraction_matches_share_multigroup_bitwise() {
        let shape = two_socket_shape(40.0);
        let groups = [
            RemoteGroup { home: 0, n: 4, f: 0.84, bs_gbs: 32.0, remote_frac: 0.0, kind: GroupKind::Mem },
            RemoteGroup { home: 0, n: 4, f: 0.75, bs_gbs: 33.0, remote_frac: 0.0, kind: GroupKind::Mem },
            RemoteGroup { home: 2, n: 6, f: 0.30, bs_gbs: 35.0, remote_frac: 0.0, kind: GroupKind::Mem },
        ];
        let remote = share_remote(&shape, &groups).unwrap();
        let d0 = share_multigroup(&[
            KernelGroup { n: 4, f: 0.84, bs_gbs: 32.0 },
            KernelGroup { n: 4, f: 0.75, bs_gbs: 33.0 },
        ]);
        let d2 = share_multigroup(&[KernelGroup { n: 6, f: 0.30, bs_gbs: 35.0 }]);
        assert_eq!(remote.per_core_gbs[0].to_bits(), d0.groups[0].per_core_gbs.to_bits());
        assert_eq!(remote.per_core_gbs[1].to_bits(), d0.groups[1].per_core_gbs.to_bits());
        assert_eq!(remote.per_core_gbs[2].to_bits(), d2.groups[0].per_core_gbs.to_bits());
        assert_eq!(remote.domains[0].b_mix_gbs.to_bits(), d0.b_mix_gbs.to_bits());
        assert_eq!(remote.domains[2].b_mix_gbs.to_bits(), d2.b_mix_gbs.to_bits());
        // No portion crosses a link, and no gating -> one pass.
        assert!(remote.portions.iter().all(|p| p.link.is_none()));
        assert_eq!(remote.links.len(), 2);
        assert_eq!(remote.links[0].demand_gbs, 0.0);
        assert_eq!(remote.links[1].demand_gbs, 0.0);
        assert_eq!(remote.iterations, 1);
    }

    /// A symmetric intra-socket spread is invisible: every domain receives
    /// exactly the traffic it exports, so rates match the local case.
    #[test]
    fn symmetric_intra_socket_spread_is_neutral() {
        let shape = TopoShape {
            socket_of: vec![0, 0],
            bw_scale: vec![1.0, 1.0],
            link_bw_gbs: 0.0,
            link_bw_rev_gbs: 0.0,
            l3_bw_gbs: 0.0,
        };
        let local = share_remote(
            &shape,
            &[
                RemoteGroup { home: 0, n: 8, f: 0.8, bs_gbs: 32.0, remote_frac: 0.0, kind: GroupKind::Mem },
                RemoteGroup { home: 1, n: 8, f: 0.8, bs_gbs: 32.0, remote_frac: 0.0, kind: GroupKind::Mem },
            ],
        )
        .unwrap();
        let spread = share_remote(
            &shape,
            &[
                RemoteGroup { home: 0, n: 8, f: 0.8, bs_gbs: 32.0, remote_frac: 0.5, kind: GroupKind::Mem },
                RemoteGroup { home: 1, n: 8, f: 0.8, bs_gbs: 32.0, remote_frac: 0.5, kind: GroupKind::Mem },
            ],
        )
        .unwrap();
        for (a, b) in local.per_core_gbs.iter().zip(&spread.per_core_gbs) {
            assert!((a - b).abs() < 1e-9 * a.max(1.0), "{a} vs {b}");
        }
    }

    /// A slow link gates the whole stream: shrinking the link shrinks the
    /// group bandwidth once the link saturates.
    #[test]
    fn saturated_link_bottlenecks_the_stream() {
        let mk = |link_bw: f64| {
            let shape = TopoShape {
                socket_of: vec![0, 1],
                bw_scale: vec![1.0, 1.0],
                link_bw_gbs: link_bw,
                link_bw_rev_gbs: link_bw,
                l3_bw_gbs: 0.0,
            };
            share_remote(
                &shape,
                &[RemoteGroup { home: 0, n: 8, f: 0.8, bs_gbs: 32.0, remote_frac: 0.5, kind: GroupKind::Mem }],
            )
            .unwrap()
        };
        let wide = mk(1000.0);
        let narrow = mk(2.0);
        assert!(narrow.links[0].saturated);
        assert!(!wide.links[0].saturated);
        assert!(
            narrow.per_core_gbs[0] < wide.per_core_gbs[0],
            "narrow {} !< wide {}",
            narrow.per_core_gbs[0],
            wide.per_core_gbs[0]
        );
        // The link-gated per-core rate is exactly link_grant / (n w).
        let p = narrow.portions.iter().find(|p| p.link.is_some()).unwrap();
        let expect = p.granted_bw_gbs / (8.0 * p.weight);
        assert!((narrow.per_core_gbs[0] - expect).abs() < 1e-12);
    }

    #[test]
    fn remote_validation_errors() {
        let single = TopoShape {
            socket_of: vec![0],
            bw_scale: vec![1.0],
            link_bw_gbs: 0.0,
            link_bw_rev_gbs: 0.0,
            l3_bw_gbs: 0.0,
        };
        let g = RemoteGroup { home: 0, n: 2, f: 0.5, bs_gbs: 50.0, remote_frac: 0.5, kind: GroupKind::Mem };
        assert!(share_remote(&single, &[g]).is_err(), "remote needs >= 2 domains");
        let shape = two_socket_shape(10.0);
        let bad_frac = RemoteGroup { remote_frac: 1.5, ..g };
        assert!(share_remote(&shape, &[bad_frac]).is_err());
        let bad_home = RemoteGroup { home: 9, ..g };
        assert!(share_remote(&shape, &[bad_home]).is_err());
        // r = 1 (no home traffic at all) is legal.
        let all_remote = RemoteGroup { remote_frac: 1.0, ..g };
        let share = share_remote(&shape, &[all_remote]).unwrap();
        assert!(share.per_core_gbs[0] > 0.0);
        assert!(share.portions.iter().all(|p| p.target != 0 || p.weight > 0.0));
    }

    #[test]
    fn rate_model_memoizes_global_compositions() {
        let shape = two_socket_shape(64.0);
        let mut model = RemoteRateModel::new(
            shape,
            vec![0.25; 4],
            vec![(0.84, 32.0), (0.30, 35.0)],
        );
        assert_eq!(model.slots(), 2);
        let counts = vec![4u16, 0, 0, 2, 0, 0, 0, 0];
        let a = model.rates_bytes(&counts).to_vec();
        let b = model.rates_bytes(&counts).to_vec();
        assert_eq!(a.len(), 8);
        assert!(a[0] > 0.0 && a[3] > 0.0);
        assert_eq!(a[1], 0.0, "empty slots drain nothing");
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.to_bits(), y.to_bits());
        }
        let (hits, misses, entries) = model.stats();
        assert_eq!((hits, misses, entries), (1, 1, 1));
    }

    #[test]
    #[should_panic(expected = "remote fraction")]
    fn rate_model_rejects_bad_fractions() {
        RemoteRateModel::new(two_socket_shape(1.0), vec![2.0; 4], vec![(0.5, 30.0)]);
    }
}
