//! Crate-wide error type (hand-rolled — the offline build has no external
//! error-derive crate).

use std::fmt;

/// Result alias used across the crate.
pub type Result<T> = std::result::Result<T, Error>;

/// All failure modes of the coordinator.
#[derive(Debug)]
pub enum Error {
    /// An unknown machine id was requested from the registry.
    UnknownMachine(String, String),

    /// An unknown kernel name was requested from the registry.
    UnknownKernel(String, String),

    /// A configuration file failed to parse.
    Config {
        /// Path of the offending file.
        path: String,
        /// What went wrong.
        msg: String,
    },

    /// An experiment plan is inconsistent (e.g. thread counts exceed domain).
    InvalidPlan(String),

    /// The PJRT runtime failed (client creation, artifact load, execution).
    Runtime(String),

    /// An AOT artifact is missing — run `make artifacts` first.
    MissingArtifact(String),

    /// A simulation failed to converge to steady state.
    NoSteadyState(String),

    /// Any I/O failure.
    Io(std::io::Error),
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Error::UnknownMachine(name, known) => {
                write!(f, "unknown machine '{name}' (known: {known})")
            }
            Error::UnknownKernel(name, known) => {
                write!(f, "unknown kernel '{name}' (known: {known})")
            }
            Error::Config { path, msg } => write!(f, "config error in {path}: {msg}"),
            Error::InvalidPlan(msg) => write!(f, "invalid plan: {msg}"),
            Error::Runtime(msg) => write!(f, "runtime error: {msg}"),
            Error::MissingArtifact(path) => {
                write!(f, "artifact not found: {path} (run `make artifacts`)")
            }
            Error::NoSteadyState(msg) => {
                write!(f, "simulation did not reach steady state: {msg}")
            }
            Error::Io(e) => write!(f, "io error: {e}"),
        }
    }
}

impl std::error::Error for Error {
    fn source(&self) -> Option<&(dyn std::error::Error + 'static)> {
        match self {
            Error::Io(e) => Some(e),
            _ => None,
        }
    }
}

impl From<std::io::Error> for Error {
    fn from(e: std::io::Error) -> Self {
        Error::Io(e)
    }
}

impl Error {
    /// Convenience constructor for runtime errors from the `xla` crate.
    pub fn runtime<E: std::fmt::Display>(e: E) -> Self {
        Error::Runtime(e.to_string())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn messages_keep_key_substrings() {
        assert!(Error::MissingArtifact("a.hlo".into()).to_string().contains("make artifacts"));
        let c = Error::Config { path: "m.toml".into(), msg: "missing key".into() };
        assert!(c.to_string().contains("m.toml"));
        let io: Error = std::io::Error::new(std::io::ErrorKind::NotFound, "gone").into();
        assert!(io.to_string().contains("io error"));
    }
}
