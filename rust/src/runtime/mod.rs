//! PJRT runtime: loads the AOT-compiled JAX/Pallas artifacts
//! (`artifacts/*.hlo.txt`) and executes them from the coordinator's hot
//! path. Python never runs here.
//!
//! * [`artifact`] — artifact discovery + geometry metadata,
//! * [`client`] — PJRT CPU client and executable wrappers,
//! * [`executor`] — high-level batched simulation / analytic-model
//!   execution (packing [`crate::simulator::CoreWorkload`]s into the
//!   artifact's `[B, N]` planes and unpacking bandwidths).

mod artifact;
mod client;
mod executor;

pub use artifact::{ArtifactMeta, ArtifactPaths};
pub use client::{PjrtExecutable, PjrtRuntime};
pub use executor::{PjrtSimExecutor, SimCase};
