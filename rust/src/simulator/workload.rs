//! Per-core workload parameters fed to the simulators.
//!
//! A workload is fully characterized by the intrinsic demand rate of the
//! core (lines per cycle it would consume without contention) and the
//! service-cost factor of its line mix — the simulator-level reflection of
//! the paper's claim that only `f` and `b_s` matter.

use crate::config::Machine;
use crate::ecm;
use crate::kernels::KernelSignature;

/// Parameters of one simulated core's workload.
#[derive(Debug, Clone, Copy)]
pub struct CoreWorkload {
    /// Intrinsic single-core demand in cache lines per cycle
    /// (`mem_lines / T_ECM` from the ECM analysis).
    pub demand_lines_per_cy: f64,
    /// Service-cost factor of the kernel's line mix (1.0 = pure reads).
    pub cost_factor: f64,
    /// Memory request fraction predicted by ECM (`d * c / C`); used for the
    /// latency-penalty term.
    pub f_ecm: f64,
    /// Group tag for bookkeeping (kernel I = 0, kernel II = 1, ...).
    pub group: usize,
}

impl CoreWorkload {
    /// Derive the workload of `kernel` on `machine` via the ECM model.
    pub fn from_kernel(kernel: &KernelSignature, machine: &Machine, group: usize) -> Self {
        let p = ecm::predict(kernel, machine);
        CoreWorkload {
            demand_lines_per_cy: p.demand_lines_per_cy,
            cost_factor: p.cost_factor,
            f_ecm: p.f,
            group,
        }
    }

    /// An idle core (scenario (c) of Fig. 2): zero demand.
    pub fn idle() -> Self {
        CoreWorkload { demand_lines_per_cy: 0.0, cost_factor: 1.0, f_ecm: 0.0, group: usize::MAX }
    }

    /// The same stream thinned to a fraction `scale` of its line rate,
    /// re-tagged as `group`: a core that sends only part of its lines to
    /// an interface looks, to that interface, like a core of
    /// proportionally lower demand. The multi-interface engines
    /// (`simulator::network`) thin per routed portion internally; this
    /// helper remains for ad-hoc workload construction.
    pub fn thinned(&self, scale: f64, group: usize) -> Self {
        CoreWorkload {
            demand_lines_per_cy: self.demand_lines_per_cy * scale,
            cost_factor: self.cost_factor,
            f_ecm: self.f_ecm * scale,
            group,
        }
    }

    /// Whether this core issues any memory traffic.
    pub fn is_active(&self) -> bool {
        self.demand_lines_per_cy > 0.0
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{machine, MachineId};
    use crate::kernels::{kernel, KernelId};

    #[test]
    fn workload_consistent_with_ecm_f() {
        let m = machine(MachineId::Bdw1);
        let k = kernel(KernelId::Stream);
        let w = CoreWorkload::from_kernel(&k, &m, 0);
        // f = d * c / C must reproduce the ECM request fraction.
        let f = w.demand_lines_per_cy * w.cost_factor / m.capacity_lines_per_cy();
        assert!((f - w.f_ecm).abs() < 1e-9);
    }

    #[test]
    fn idle_core_is_inactive() {
        assert!(!CoreWorkload::idle().is_active());
    }

    #[test]
    fn thinned_scales_demand_linearly() {
        let m = machine(MachineId::Bdw1);
        let w = CoreWorkload::from_kernel(&kernel(KernelId::Stream), &m, 0);
        let t = w.thinned(0.25, 7);
        assert_eq!(t.group, 7);
        assert!((t.demand_lines_per_cy - 0.25 * w.demand_lines_per_cy).abs() < 1e-15);
        assert_eq!(t.cost_factor.to_bits(), w.cost_factor.to_bits());
        assert!((t.f_ecm - 0.25 * w.f_ecm).abs() < 1e-15);
    }
}
