//! Remote-access extension of the sharing model: multi-socket and SNC
//! topologies where part of a group's cache-line stream leaves its home
//! ccNUMA domain.
//!
//! The paper's Eqs. (4)+(5) assume all traffic of a contention domain stays
//! on that domain's memory interface. Real multi-socket machines (and the
//! paper's own dual-socket testbed) violate this whenever data is placed
//! remotely: a line then contends on the *target* domain's memory interface
//! and, if the target sits on another socket, additionally on the
//! inter-socket link (QPI/UPI on Intel, xGMI on Rome).
//!
//! This module models that with three deliberate simplifications (all
//! documented in `docs/MODEL.md`):
//!
//! 1. **Uniform spread** — a group with remote fraction `r` keeps `1-r` of
//!    its stream on its home domain and spreads `r` uniformly over all
//!    other domains (the behaviour of interleaved/first-touch-miss pages).
//! 2. **Interfaces are independent Eqs. (4)+(5) instances** — every memory
//!    interface and every link evaluates the generalized water-fill over
//!    the traffic *portions* it carries ([`share_weighted`] with fractional
//!    thread counts; links use their own capacity via
//!    [`share_weighted_capacity`]). There is no global fixed point: a
//!    portion's demand is its unconstrained `n·w·f·b_s`, not the grant of
//!    the other interfaces it crosses.
//! 3. **Lockstep streams** — a core interleaves its local and remote lines
//!    in fixed proportion, so the slowest portion gates the whole stream:
//!    the per-core bandwidth of a group is `min_p grant_p / (n·w_p)` over
//!    its portions `p`.
//!
//! With `r = 0` everything collapses to one home portion of weight 1 and
//! the evaluation is bit-identical to [`share_domains`] (pinned by the
//! topology conformance suite).
//!
//! The measurement substrate simulates the *same* interface network with
//! the same portion expansion ([`crate::simulator::route_streams`] mirrors
//! the routing in [`share_remote`] one for one), so the model's water-fill
//! can be validated against simulated — not offered — link traffic; see
//! `docs/SIMULATORS.md`.
//!
//! [`share_domains`]: crate::sharing::share_domains
//!
//! # Examples
//!
//! ```
//! use membw::sharing::{share_remote, RemoteGroup, TopoShape};
//!
//! // Two sockets x one domain, 10 GB/s link.
//! let shape = TopoShape {
//!     socket_of: vec![0, 1],
//!     bw_scale: vec![1.0, 1.0],
//!     link_bw_gbs: 10.0,
//! };
//! // 8 cores on domain 0 sending a quarter of their lines to domain 1.
//! let groups = [RemoteGroup { home: 0, n: 8, f: 0.3, bs_gbs: 60.0, remote_frac: 0.25 }];
//! let share = share_remote(&shape, &groups).unwrap();
//! // The remote quarter crosses the (only) link...
//! assert_eq!(shape.links(), vec![(0, 1)]);
//! assert!(share.links[0].demand_gbs > 0.0);
//! // ...and the group cannot beat its solo bandwidth.
//! assert!(share.per_core_gbs[0] <= 0.3 * 60.0 + 1e-9);
//! ```

use std::collections::HashMap;

use crate::error::{Error, Result};
use crate::sharing::multigroup::{share_weighted, share_weighted_capacity, WeightedGroup};

/// The shape of a topology as the remote model sees it: which socket each
/// ccNUMA domain belongs to, the per-domain bandwidth scales, and the
/// saturated bandwidth of one inter-socket link.
#[derive(Debug, Clone, PartialEq)]
pub struct TopoShape {
    /// Socket of each domain, in domain order.
    pub socket_of: Vec<usize>,
    /// Saturated-bandwidth scale of each domain (1.0 = nominal).
    pub bw_scale: Vec<f64>,
    /// Saturated bandwidth of one inter-socket link, GB/s per socket pair
    /// (0 = links not modeled; remote traffic then only contends on the
    /// target domain's memory interface).
    pub link_bw_gbs: f64,
}

impl TopoShape {
    /// Number of ccNUMA domains.
    pub fn n_domains(&self) -> usize {
        self.socket_of.len()
    }

    /// Number of sockets.
    pub fn n_sockets(&self) -> usize {
        self.socket_of.iter().copied().max().map_or(0, |s| s + 1)
    }

    /// The inter-socket links: all unordered socket pairs, lexicographic.
    /// Each is one contention interface of capacity [`TopoShape::link_bw_gbs`].
    pub fn links(&self) -> Vec<(usize, usize)> {
        let s = self.n_sockets();
        let mut out = Vec::new();
        for a in 0..s {
            for b in (a + 1)..s {
                out.push((a, b));
            }
        }
        out
    }
}

/// The shared portion-routing rule of model and measurement: the slices
/// of one stream homed on `home` with remote fraction `remote_frac`, as
/// `(target domain, link index, weight)` triples — the home portion of
/// weight `1-r` first (omitted at `r = 1`), then `r/(D-1)` per remote
/// target in domain order, with the socket pair's link attached when the
/// target lives on another socket and `links_modeled` is set.
///
/// [`share_remote`] expands its analytic groups through this function and
/// the simulation substrate routes its per-core streams through the very
/// same one (`route_streams` in `simulator::network`), so the two sides
/// cannot drift apart.
///
/// The caller validates inputs first: `remote_frac` must be in `[0, 1]`,
/// `home` in range, and `remote_frac > 0` needs at least two domains.
pub fn portion_routes(
    socket_of: &[usize],
    links: &[(usize, usize)],
    links_modeled: bool,
    home: usize,
    remote_frac: f64,
) -> Vec<(usize, Option<usize>, f64)> {
    let nd = socket_of.len();
    let mut out = Vec::new();
    let home_w = 1.0 - remote_frac;
    if home_w > 0.0 {
        out.push((home, None, home_w));
    }
    if remote_frac > 0.0 {
        let w = remote_frac / (nd - 1) as f64;
        for t in 0..nd {
            if t == home {
                continue;
            }
            let link = if socket_of[t] != socket_of[home] && links_modeled {
                let pair = (socket_of[home].min(socket_of[t]), socket_of[home].max(socket_of[t]));
                links.iter().position(|&l| l == pair)
            } else {
                None
            };
            out.push((t, link, w));
        }
    }
    out
}

/// One kernel group resident on a home domain, with a remote-access split.
#[derive(Debug, Clone, Copy)]
pub struct RemoteGroup {
    /// Home domain (where the group's cores are pinned).
    pub home: usize,
    /// Cores in the group.
    pub n: usize,
    /// Memory request fraction of the kernel (Eq. 2).
    pub f: f64,
    /// Nominal (unscaled) saturated bandwidth of the kernel, GB/s; the
    /// per-domain scale of the *target* domain is applied per portion.
    pub bs_gbs: f64,
    /// Fraction of the group's cache-line stream that goes to remote
    /// domains (uniformly spread); in `[0, 1]`.
    pub remote_frac: f64,
}

/// One traffic portion of a group: the slice of its line stream aimed at
/// one target domain (and possibly crossing one inter-socket link).
#[derive(Debug, Clone, Copy)]
pub struct Portion {
    /// Index of the group in the input slice.
    pub group: usize,
    /// Target domain of the portion.
    pub target: usize,
    /// Fraction of the group's stream in this portion.
    pub weight: f64,
    /// Index into [`TopoShape::links`] if the portion crosses sockets
    /// (None when intra-socket or when links are not modeled).
    pub link: Option<usize>,
    /// Water-fill grant on the target memory interface, GB/s.
    pub mem_bw_gbs: f64,
    /// Water-fill grant on the link (only meaningful when `link` is set).
    pub link_grant_gbs: f64,
    /// Effective grant: the minimum of the two, GB/s.
    pub granted_bw_gbs: f64,
}

/// Summary of one contention interface (a domain's memory interface or an
/// inter-socket link).
#[derive(Debug, Clone, Copy, Default)]
pub struct InterfaceShare {
    /// Capacity of the interface under its traffic mix, GB/s (generalized
    /// Eq. 4 for memory interfaces; `link_bw` for links).
    pub b_mix_gbs: f64,
    /// Total unconstrained demand offered to the interface, GB/s.
    pub demand_gbs: f64,
    /// Whether demand meets or exceeds capacity.
    pub saturated: bool,
}

/// Result of the remote-aware sharing evaluation.
#[derive(Debug, Clone)]
pub struct RemoteShare {
    /// Per-core bandwidth of each input group after the lockstep-stream
    /// bottleneck, GB/s.
    pub per_core_gbs: Vec<f64>,
    /// Aggregate bandwidth of each input group (`n ·` per-core), GB/s.
    pub group_bw_gbs: Vec<f64>,
    /// Per-domain memory-interface summaries.
    pub domains: Vec<InterfaceShare>,
    /// Per-link summaries, parallel to [`TopoShape::links`].
    pub links: Vec<InterfaceShare>,
    /// All traffic portions with their grants (reporting detail).
    pub portions: Vec<Portion>,
}

/// Evaluate the remote-aware sharing model over `groups` on `shape`.
///
/// Fails when a remote fraction is outside `[0, 1]`, when a group with
/// remote traffic sits on a single-domain shape, or when a home domain is
/// out of range.
pub fn share_remote(shape: &TopoShape, groups: &[RemoteGroup]) -> Result<RemoteShare> {
    let nd = shape.n_domains();
    let links = shape.links();

    // 1. Expand groups into traffic portions.
    let mut portions: Vec<Portion> = Vec::new();
    for (gi, g) in groups.iter().enumerate() {
        if !g.remote_frac.is_finite() || !(0.0..=1.0).contains(&g.remote_frac) {
            return Err(Error::InvalidPlan(format!(
                "remote fraction {} of group {gi} outside [0, 1]",
                g.remote_frac
            )));
        }
        if g.home >= nd {
            return Err(Error::InvalidPlan(format!(
                "group {gi} homed on domain d{} but the shape has {nd} domains",
                g.home
            )));
        }
        if g.remote_frac > 0.0 && nd < 2 {
            return Err(Error::InvalidPlan(
                "remote accesses need at least two ccNUMA domains".into(),
            ));
        }
        for (target, link, weight) in
            portion_routes(&shape.socket_of, &links, shape.link_bw_gbs > 0.0, g.home, g.remote_frac)
        {
            portions.push(Portion {
                group: gi,
                target,
                weight,
                link,
                mem_bw_gbs: 0.0,
                link_grant_gbs: 0.0,
                granted_bw_gbs: 0.0,
            });
        }
    }

    // 2. Every memory interface runs the generalized Eqs. (4)+(5) over the
    // portions it carries.
    let mut domains = vec![InterfaceShare::default(); nd];
    for (d, dom_share) in domains.iter_mut().enumerate() {
        let idx: Vec<usize> = (0..portions.len()).filter(|&p| portions[p].target == d).collect();
        if idx.is_empty() {
            continue;
        }
        let wg: Vec<WeightedGroup> = idx
            .iter()
            .map(|&p| {
                let g = &groups[portions[p].group];
                WeightedGroup {
                    n: g.n as f64 * portions[p].weight,
                    f: g.f,
                    bs_gbs: g.bs_gbs * shape.bw_scale[d],
                }
            })
            .collect();
        let share = share_weighted(&wg);
        for (k, &p) in idx.iter().enumerate() {
            portions[p].mem_bw_gbs = share.groups[k].group_bw_gbs;
        }
        *dom_share = InterfaceShare {
            b_mix_gbs: share.b_mix_gbs,
            demand_gbs: wg.iter().map(|g| g.n * g.f * g.bs_gbs).sum(),
            saturated: share.saturated,
        };
    }

    // 3. Every link runs the same water-fill at its own capacity; a
    // portion's demand is still that of the memory stream it ships.
    let mut link_shares = vec![InterfaceShare::default(); links.len()];
    for (li, link_share) in link_shares.iter_mut().enumerate() {
        let idx: Vec<usize> =
            (0..portions.len()).filter(|&p| portions[p].link == Some(li)).collect();
        if idx.is_empty() {
            continue;
        }
        let wg: Vec<WeightedGroup> = idx
            .iter()
            .map(|&p| {
                let g = &groups[portions[p].group];
                WeightedGroup {
                    n: g.n as f64 * portions[p].weight,
                    f: g.f,
                    bs_gbs: g.bs_gbs * shape.bw_scale[portions[p].target],
                }
            })
            .collect();
        let share = share_weighted_capacity(&wg, shape.link_bw_gbs);
        for (k, &p) in idx.iter().enumerate() {
            portions[p].link_grant_gbs = share.groups[k].group_bw_gbs;
        }
        *link_share = InterfaceShare {
            b_mix_gbs: shape.link_bw_gbs,
            demand_gbs: wg.iter().map(|g| g.n * g.f * g.bs_gbs).sum(),
            saturated: share.saturated,
        };
    }

    // 4. Combine: a cross-socket portion is gated by the slower of its two
    // interfaces; the group by its slowest portion (lockstep streams).
    for p in portions.iter_mut() {
        p.granted_bw_gbs = match p.link {
            Some(_) => p.mem_bw_gbs.min(p.link_grant_gbs),
            None => p.mem_bw_gbs,
        };
    }
    let mut per_core_gbs = vec![0.0f64; groups.len()];
    let mut group_bw_gbs = vec![0.0f64; groups.len()];
    for (gi, g) in groups.iter().enumerate() {
        if g.n == 0 {
            continue;
        }
        let mut rate = f64::INFINITY;
        for p in portions.iter().filter(|p| p.group == gi) {
            rate = rate.min(p.granted_bw_gbs / (g.n as f64 * p.weight));
        }
        if !rate.is_finite() {
            rate = 0.0;
        }
        per_core_gbs[gi] = rate;
        group_bw_gbs[gi] = rate * g.n as f64;
    }

    Ok(RemoteShare { per_core_gbs, group_bw_gbs, domains, links: link_shares, portions })
}

/// Upper bound on memoized compositions in a [`RemoteRateModel`]: far
/// above what a co-sim revisits (hundreds), low enough that the map can
/// never grow with simulated time.
const MAX_CACHED_COMPOSITIONS: usize = 4096;

/// Memoized remote-aware rate evaluation for the contention-timeline
/// engine: a global composition (core counts per `(domain, kernel)` slot)
/// maps to per-slot per-core drain rates in bytes/s.
///
/// Unlike the per-domain [`crate::sharing::ShareCache`], remote traffic
/// couples every domain (and the links), so the whole composition is one
/// cache key and one [`share_remote`] evaluation.
pub struct RemoteRateModel {
    shape: TopoShape,
    /// Remote fraction per home domain.
    frac: Vec<f64>,
    /// `(f, b_s[GB/s])` per kernel slot (nominal, unscaled).
    chars: Vec<(f64, f64)>,
    cache: HashMap<Vec<u16>, Vec<f64>>,
    hits: u64,
    misses: u64,
}

impl RemoteRateModel {
    /// Build a model for `shape` with per-domain remote fractions `frac`
    /// and per-slot kernel characterizations `chars` (`(f, b_s)` in slot
    /// order).
    ///
    /// # Panics
    /// If `frac` does not cover every domain, a fraction is outside
    /// `[0, 1]`, or remote traffic is requested on a single-domain shape —
    /// all programming errors of the caller (the layout is validated at
    /// construction time in [`crate::topology::RankLayout::with_remote`]).
    pub fn new(shape: TopoShape, frac: Vec<f64>, chars: Vec<(f64, f64)>) -> Self {
        assert_eq!(frac.len(), shape.n_domains(), "one remote fraction per domain");
        for &r in &frac {
            assert!(
                r.is_finite() && (0.0..=1.0).contains(&r),
                "remote fraction {r} outside [0, 1]"
            );
        }
        assert!(
            shape.n_domains() >= 2 || frac.iter().all(|&r| r == 0.0),
            "remote accesses need at least two ccNUMA domains"
        );
        RemoteRateModel { shape, frac, chars, cache: HashMap::new(), hits: 0, misses: 0 }
    }

    /// Number of kernel slots.
    pub fn slots(&self) -> usize {
        self.chars.len()
    }

    /// One uncached evaluation of the global composition `counts`.
    fn compute(
        shape: &TopoShape,
        frac: &[f64],
        chars: &[(f64, f64)],
        counts: &[u16],
    ) -> Vec<f64> {
        let nk = chars.len();
        let mut slots: Vec<usize> = Vec::new();
        let mut groups: Vec<RemoteGroup> = Vec::new();
        for d in 0..shape.n_domains() {
            for (k, &(f, bs)) in chars.iter().enumerate() {
                let c = counts[d * nk + k];
                if c > 0 {
                    slots.push(d * nk + k);
                    groups.push(RemoteGroup {
                        home: d,
                        n: c as usize,
                        f,
                        bs_gbs: bs,
                        remote_frac: frac[d],
                    });
                }
            }
        }
        let mut rates = vec![0.0f64; counts.len()];
        if !groups.is_empty() {
            let share = share_remote(shape, &groups)
                .expect("shape and fractions validated at construction");
            for (i, &slot) in slots.iter().enumerate() {
                rates[slot] = share.per_core_gbs[i] * 1e9;
            }
        }
        rates
    }

    /// Per-core drain rates (bytes/s) per `(domain, kernel)` slot for the
    /// global composition `counts[d * slots + k]`. Memoized.
    // Not the entry API: that would allocate the `Vec<u16>` key on every
    // call, while `contains_key`/`get` borrow the slice directly — the hit
    // path (the timeline engine's per-event cadence) stays allocation-free.
    #[allow(clippy::map_entry)]
    pub fn rates_bytes(&mut self, counts: &[u16]) -> &[f64] {
        debug_assert_eq!(counts.len(), self.shape.n_domains() * self.chars.len());
        if self.cache.contains_key(counts) {
            self.hits += 1;
        } else {
            self.misses += 1;
            // Bound the memo: a long noisy co-sim churns compositions, and
            // unlike the 2-entry-MRU ShareCache this map would otherwise
            // grow with simulated time. A wholesale reset is cheap and
            // keeps results deterministic (entries are pure functions).
            if self.cache.len() >= MAX_CACHED_COMPOSITIONS {
                self.cache.clear();
            }
            let rates = Self::compute(&self.shape, &self.frac, &self.chars, counts);
            self.cache.insert(counts.to_vec(), rates);
        }
        self.cache.get(counts).expect("present or just inserted").as_slice()
    }

    /// `(hits, misses, entries)` counter snapshot.
    pub fn stats(&self) -> (u64, u64, usize) {
        (self.hits, self.misses, self.cache.len())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sharing::{share_multigroup, KernelGroup};

    fn two_socket_shape(link_bw: f64) -> TopoShape {
        TopoShape { socket_of: vec![0, 0, 1, 1], bw_scale: vec![1.0; 4], link_bw_gbs: link_bw }
    }

    #[test]
    fn shape_links_enumerate_socket_pairs() {
        assert_eq!(two_socket_shape(10.0).links(), vec![(0, 1)]);
        let four =
            TopoShape { socket_of: vec![0, 1, 2, 3], bw_scale: vec![1.0; 4], link_bw_gbs: 1.0 };
        assert_eq!(four.links(), vec![(0, 1), (0, 2), (0, 3), (1, 2), (1, 3), (2, 3)]);
        assert_eq!(four.n_sockets(), 4);
    }

    /// r = 0 collapses to the per-domain evaluation, bit for bit.
    #[test]
    fn zero_remote_fraction_matches_share_multigroup_bitwise() {
        let shape = two_socket_shape(40.0);
        let groups = [
            RemoteGroup { home: 0, n: 4, f: 0.84, bs_gbs: 32.0, remote_frac: 0.0 },
            RemoteGroup { home: 0, n: 4, f: 0.75, bs_gbs: 33.0, remote_frac: 0.0 },
            RemoteGroup { home: 2, n: 6, f: 0.30, bs_gbs: 35.0, remote_frac: 0.0 },
        ];
        let remote = share_remote(&shape, &groups).unwrap();
        let d0 = share_multigroup(&[
            KernelGroup { n: 4, f: 0.84, bs_gbs: 32.0 },
            KernelGroup { n: 4, f: 0.75, bs_gbs: 33.0 },
        ]);
        let d2 = share_multigroup(&[KernelGroup { n: 6, f: 0.30, bs_gbs: 35.0 }]);
        assert_eq!(remote.per_core_gbs[0].to_bits(), d0.groups[0].per_core_gbs.to_bits());
        assert_eq!(remote.per_core_gbs[1].to_bits(), d0.groups[1].per_core_gbs.to_bits());
        assert_eq!(remote.per_core_gbs[2].to_bits(), d2.groups[0].per_core_gbs.to_bits());
        assert_eq!(remote.domains[0].b_mix_gbs.to_bits(), d0.b_mix_gbs.to_bits());
        assert_eq!(remote.domains[2].b_mix_gbs.to_bits(), d2.b_mix_gbs.to_bits());
        // No portion crosses a link.
        assert!(remote.portions.iter().all(|p| p.link.is_none()));
        assert_eq!(remote.links.len(), 1);
        assert_eq!(remote.links[0].demand_gbs, 0.0);
    }

    /// A symmetric intra-socket spread is invisible: every domain receives
    /// exactly the traffic it exports, so rates match the local case.
    #[test]
    fn symmetric_intra_socket_spread_is_neutral() {
        let shape = TopoShape { socket_of: vec![0, 0], bw_scale: vec![1.0, 1.0], link_bw_gbs: 0.0 };
        let local = share_remote(
            &shape,
            &[
                RemoteGroup { home: 0, n: 8, f: 0.8, bs_gbs: 32.0, remote_frac: 0.0 },
                RemoteGroup { home: 1, n: 8, f: 0.8, bs_gbs: 32.0, remote_frac: 0.0 },
            ],
        )
        .unwrap();
        let spread = share_remote(
            &shape,
            &[
                RemoteGroup { home: 0, n: 8, f: 0.8, bs_gbs: 32.0, remote_frac: 0.5 },
                RemoteGroup { home: 1, n: 8, f: 0.8, bs_gbs: 32.0, remote_frac: 0.5 },
            ],
        )
        .unwrap();
        for (a, b) in local.per_core_gbs.iter().zip(&spread.per_core_gbs) {
            assert!((a - b).abs() < 1e-9 * a.max(1.0), "{a} vs {b}");
        }
    }

    /// A slow link gates the whole stream: shrinking the link shrinks the
    /// group bandwidth once the link saturates.
    #[test]
    fn saturated_link_bottlenecks_the_stream() {
        let mk = |link_bw: f64| {
            let shape = TopoShape {
                socket_of: vec![0, 1],
                bw_scale: vec![1.0, 1.0],
                link_bw_gbs: link_bw,
            };
            share_remote(
                &shape,
                &[RemoteGroup { home: 0, n: 8, f: 0.8, bs_gbs: 32.0, remote_frac: 0.5 }],
            )
            .unwrap()
        };
        let wide = mk(1000.0);
        let narrow = mk(2.0);
        assert!(narrow.links[0].saturated);
        assert!(!wide.links[0].saturated);
        assert!(
            narrow.per_core_gbs[0] < wide.per_core_gbs[0],
            "narrow {} !< wide {}",
            narrow.per_core_gbs[0],
            wide.per_core_gbs[0]
        );
        // The link-gated per-core rate is exactly link_grant / (n w).
        let p = narrow.portions.iter().find(|p| p.link.is_some()).unwrap();
        let expect = p.granted_bw_gbs / (8.0 * p.weight);
        assert!((narrow.per_core_gbs[0] - expect).abs() < 1e-12);
    }

    #[test]
    fn remote_validation_errors() {
        let single = TopoShape { socket_of: vec![0], bw_scale: vec![1.0], link_bw_gbs: 0.0 };
        let g = RemoteGroup { home: 0, n: 2, f: 0.5, bs_gbs: 50.0, remote_frac: 0.5 };
        assert!(share_remote(&single, &[g]).is_err(), "remote needs >= 2 domains");
        let shape = two_socket_shape(10.0);
        let bad_frac = RemoteGroup { remote_frac: 1.5, ..g };
        assert!(share_remote(&shape, &[bad_frac]).is_err());
        let bad_home = RemoteGroup { home: 9, ..g };
        assert!(share_remote(&shape, &[bad_home]).is_err());
        // r = 1 (no home traffic at all) is legal.
        let all_remote = RemoteGroup { remote_frac: 1.0, ..g };
        let share = share_remote(&shape, &[all_remote]).unwrap();
        assert!(share.per_core_gbs[0] > 0.0);
        assert!(share.portions.iter().all(|p| p.target != 0 || p.weight > 0.0));
    }

    #[test]
    fn rate_model_memoizes_global_compositions() {
        let shape = two_socket_shape(64.0);
        let mut model = RemoteRateModel::new(
            shape,
            vec![0.25; 4],
            vec![(0.84, 32.0), (0.30, 35.0)],
        );
        assert_eq!(model.slots(), 2);
        let counts = vec![4u16, 0, 0, 2, 0, 0, 0, 0];
        let a = model.rates_bytes(&counts).to_vec();
        let b = model.rates_bytes(&counts).to_vec();
        assert_eq!(a.len(), 8);
        assert!(a[0] > 0.0 && a[3] > 0.0);
        assert_eq!(a[1], 0.0, "empty slots drain nothing");
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.to_bits(), y.to_bits());
        }
        let (hits, misses, entries) = model.stats();
        assert_eq!((hits, misses, entries), (1, 1, 1));
    }

    #[test]
    #[should_panic(expected = "remote fraction")]
    fn rate_model_rejects_bad_fractions() {
        RemoteRateModel::new(two_socket_shape(1.0), vec![2.0; 4], vec![(0.5, 30.0)]);
    }
}
