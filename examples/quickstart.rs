//! Quickstart: pair two memory-bound kernels on one machine and compare the
//! analytic bandwidth-sharing model (paper Eqs. 4+5) against the simulated
//! measurement.
//!
//! ```bash
//! cargo run --release --example quickstart
//! ```

use membw::config::{machine, MachineId};
use membw::kernels::{kernel, KernelId};
use membw::sharing::{share_two_groups, KernelGroup};
use membw::simulator::{measure_f_bs, measure_pairing, Engine};

fn main() {
    // 1. Pick a machine (Cascade Lake, 20 cores) and two kernels.
    let m = machine(MachineId::Clx);
    let dcopy = kernel(KernelId::Dcopy);
    let ddot2 = kernel(KernelId::Ddot2);
    println!("machine: {} ({} cores per ccNUMA domain)\n", m.name, m.cores);

    // 2. Characterize each kernel exactly as the paper does (Eq. 3):
    //    f = b_meas(1 thread) / b_s(full domain).
    let c1 = measure_f_bs(&dcopy, &m, Engine::Fluid);
    let c2 = measure_f_bs(&ddot2, &m, Engine::Fluid);
    println!("DCOPY : b1 = {:5.2} GB/s, b_s = {:6.2} GB/s, f = {:.3}", c1.b1_gbs, c1.bs_gbs, c1.f);
    println!("DDOT2 : b1 = {:5.2} GB/s, b_s = {:6.2} GB/s, f = {:.3}\n", c2.b1_gbs, c2.bs_gbs, c2.f);

    // 3. Split the domain 12 + 8 and ask the model who gets what.
    let (n1, n2) = (12, 8);
    let pred = share_two_groups(
        &KernelGroup { n: n1, f: c1.f, bs_gbs: c1.bs_gbs },
        &KernelGroup { n: n2, f: c2.f, bs_gbs: c2.bs_gbs },
    );

    // 4. "Measure" the same pairing on the simulated contention domain.
    let meas = measure_pairing(&m, &dcopy, n1, &ddot2, n2, Engine::Fluid);

    println!("{n1} DCOPY threads + {n2} DDOT2 threads:");
    println!("              model      measured   error");
    for (g, name) in [(0usize, "DCOPY"), (1, "DDOT2")] {
        let err = (meas.per_core_gbs[g] - pred.per_core_gbs[g]).abs() / pred.per_core_gbs[g];
        println!(
            "  {name:6} {:6.2} GB/s  {:6.2} GB/s   {:4.1}%  (per core)",
            pred.per_core_gbs[g],
            meas.per_core_gbs[g],
            err * 100.0
        );
    }
    println!(
        "  total  {:6.1} GB/s  {:6.1} GB/s          (overlapped b_s, Eq. 4)",
        pred.group_bw_gbs[0] + pred.group_bw_gbs[1],
        meas.total_gbs
    );
}
