"""Pure-Python mirror of the optimizer's delta evaluation
(rust/src/optimizer/delta.rs), validated against the full share_remote
re-solve of netfluid_mirror.py before the Rust port.

The claim under test (docs/OPTIMIZER.md, "delta-evaluation invariant"):

    A candidate move changes the (home, remote_frac) of a subset of
    groups. Re-running the pass-1 water-fill ONLY on the interfaces whose
    portion inputs changed -- and copying every other portion's grant from
    the incumbent fill, keyed by (group, target) -- reproduces the full
    pass-1 fill bit for bit. Gating detection on those grants is then
    also bit-identical, and the gated minority falls back to the full
    Gauss-Seidel solve (which IS the reference), so the final per-group
    rates are bit-identical to share_remote on every composition.

Why the dirty set is what it is:

* A mem interface d is dirty iff some changed group's portion weight at
  target d differs from before (home moves swap the 1-r / r/(D-1)
  weights of the two endpoints; a remote-fraction retune changes every
  weight of the group). Portions of UNchanged groups at d keep identical
  (n*w, f, bs*scale[d]) inputs; portions of changed groups with equal
  weight do too, because weight values r/(D-1) are computed by the same
  expression from the same operands.
* A directed link is dirty iff a changed group's portion enters, leaves,
  or changes weight on it (a cross-socket home move redirects portions
  to the other direction; an intra-socket move keeps link ids and
  weights).
* Cache-topology extension: an L3-kind group's portions live only on its
  home socket's L3 node (weight 1.0) plus, when it still streams DRAM
  traffic, a tandem mem portion on its home domain. A home move
  therefore dirties exactly the two sockets' L3 nodes and -- iff the
  tandem exists -- the two home mem interfaces. Compute-bound groups own
  no portions and dirty nothing.
* Member ORDER per interface is stable under clean-ness: portions are
  group-major with targets ascending, each group has at most one
  mem-stage portion per target and at most one L3 portion, so a clean
  interface sees the same members in the same order -- float summation
  order (b_mix) cannot drift.

Run:  python3 python/optimizer_mirror.py
"""

import math
import random

from netfluid_mirror import (
    MACHINES,
    _expand_portions,
    _fill,
    _gkind,
    _group_rate,
    _portion_grant,
    capacity_lines_per_cy,
    net_of,
    share_remote,
    share_weighted_capped,
)


def _routes(net, home, r):
    """(target, link_or_None, weight) triples of one memory-bound group --
    the shared portion-routing rule (portion_routes in sharing/remote.rs)."""
    nd = len(net.mem_caps)
    out = []
    if 1.0 - r > 0.0:
        out.append((home, None, 1.0 - r))
    if r > 0.0:
        w = r / (nd - 1)
        for t in range(nd):
            if t == home:
                continue
            link = None
            if net.socket_of[t] != net.socket_of[home] and net.links:
                link = net.links.index((net.socket_of[home], net.socket_of[t]))
            out.append((t, link, w))
    return out


class DeltaEval:
    """Incremental pass-1 evaluator over (home, remote_frac) moves.

    Portions are the 7-tuples of _expand_portions:
    (group, target, link_or_None, weight, l3_socket_or_None,
    mem_stage_bool, cap_scale)."""

    def __init__(self, net, groups):
        self.net = net
        self.groups = list(groups)
        self.portions = _expand_portions(net, groups)
        caps = [math.inf] * len(groups)
        self.mem_grant, self.link_grant, self.l3_grant = _fill(
            net, groups, self.portions, caps)
        self.rates, self.gated = self._finish(groups, self.portions,
                                              self.mem_grant, self.link_grant,
                                              self.l3_grant)
        # Effort counters (the Rust port surfaces these through SimStats).
        self.iface_evals = (len(net.mem_caps) + len(net.links)
                            + len(net.l3_caps_gbs))
        self.iface_reused = 0
        self.full_solves = 0

    def _finish(self, groups, portions, mem_grant, link_grant, l3_grant):
        rates = [_group_rate(groups, portions, mem_grant, link_grant,
                             l3_grant, g) for g in range(len(groups))]
        gated = False
        for i, p in enumerate(portions):
            g, w = p[0], p[3]
            n = groups[g][1]
            if n == 0:
                continue
            grant = _portion_grant(portions, mem_grant, link_grant, l3_grant, i)
            if grant / (n * w) / p[6] > rates[g] * (1.0 + 1e-9):
                gated = True
        if gated:
            self_rates, _, _ = share_remote(self.net, groups)
            return self_rates, True
        return rates, False

    def dirty_set(self, changes):
        """(dirty mem domains, dirty links, dirty L3 sockets) of a move;
        changes maps group index -> new group tuple (kind never changes)."""
        net = self.net
        dirty_mem, dirty_link, dirty_l3 = set(), set(), set()
        for gi, new_g in changes.items():
            old_g = self.groups[gi]
            assert _gkind(old_g) == _gkind(new_g), "moves never change kind"
            kind = _gkind(old_g)
            if kind is not None and kind[0] == "comp":
                continue
            if kind is not None and kind[0] == "l3":
                if new_g[0] != old_g[0]:
                    dirty_l3.add(net.socket_of[old_g[0]])
                    dirty_l3.add(net.socket_of[new_g[0]])
                    if old_g[2] * old_g[3] > 0.0:
                        dirty_mem.add(old_g[0])
                        dirty_mem.add(new_g[0])
                continue
            old = {t: (l, w) for t, l, w in _routes(net, old_g[0], old_g[4])}
            new = {t: (l, w) for t, l, w in _routes(net, new_g[0], new_g[4])}
            for t in set(old) | set(new):
                lo, wo = old.get(t, (None, 0.0))
                ln, wn = new.get(t, (None, 0.0))
                if wo != wn:
                    dirty_mem.add(t)
                if (lo, wo) != (ln, wn):
                    if lo is not None:
                        dirty_link.add(lo)
                    if ln is not None:
                        dirty_link.add(ln)
        return dirty_mem, dirty_link, dirty_l3

    def eval_move(self, changes):
        """Score a move without committing: returns (rates, state) where
        state carries everything commit() needs."""
        net = self.net
        new_groups = list(self.groups)
        for gi, g in changes.items():
            new_groups[gi] = g
        new_portions = _expand_portions(net, new_groups)
        dirty_mem, dirty_link, dirty_l3 = self.dirty_set(changes)

        # Old grants keyed by (group, target), split by stage: a group has
        # at most one mem-stage portion per target, and at most one L3
        # portion (an L3 group's two portions share the same target, so a
        # single map would collide -- mirror of delta.rs old_at_mem/old_at_l3).
        old_at_mem = {(p[0], p[1]): i for i, p in enumerate(self.portions)
                      if p[5]}
        old_at_l3 = {(p[0], p[1]): i for i, p in enumerate(self.portions)
                     if p[4] is not None and not p[5]}
        nd = len(net.mem_caps)
        cap0 = capacity_lines_per_cy(net.m)
        scale = [net.mem_caps[d] / cap0 for d in range(nd)]

        mem_grant = [0.0] * len(new_portions)
        link_grant = [0.0] * len(new_portions)
        l3_grant = [0.0] * len(new_portions)
        caps = [math.inf] * len(new_groups)

        for d in range(nd):
            idx = [i for i, p in enumerate(new_portions)
                   if p[1] == d and p[5]]
            if d in dirty_mem:
                wg = [(new_groups[new_portions[i][0]][1] * new_portions[i][3],
                       new_groups[new_portions[i][0]][2],
                       new_groups[new_portions[i][0]][3] * scale[d]) for i in idx]
                n_tot = sum(g[0] for g in wg)
                self.iface_evals += 1
                if n_tot == 0.0:
                    continue
                b_mix = sum(g[0] * g[2] for g in wg) / n_tot
                rc = [caps[new_portions[i][0]] * new_portions[i][6] for i in idx]
                for i, bw in zip(idx, share_weighted_capped(wg, b_mix, rc)):
                    mem_grant[i] = bw
            else:
                for i in idx:
                    mem_grant[i] = self.mem_grant[old_at_mem[(new_portions[i][0],
                                                              new_portions[i][1])]]
                self.iface_reused += 1
        for l in range(len(net.links)):
            idx = [i for i, p in enumerate(new_portions) if p[2] == l]
            if l in dirty_link:
                if not idx:
                    self.iface_evals += 1
                    continue
                wg = [(new_groups[new_portions[i][0]][1] * new_portions[i][3],
                       new_groups[new_portions[i][0]][2],
                       new_groups[new_portions[i][0]][3] * scale[new_portions[i][1]])
                      for i in idx]
                rc = [caps[new_portions[i][0]] * new_portions[i][6] for i in idx]
                for i, bw in zip(idx, share_weighted_capped(wg, net.link_caps_gbs[l], rc)):
                    link_grant[i] = bw
                self.iface_evals += 1
            else:
                for i in idx:
                    link_grant[i] = self.link_grant[old_at_mem[(new_portions[i][0],
                                                                new_portions[i][1])]]
                self.iface_reused += 1
        for s3 in range(len(net.l3_caps_gbs)):
            idx = [i for i, p in enumerate(new_portions)
                   if p[4] == s3 and not p[5]]
            if s3 in dirty_l3:
                self.iface_evals += 1
                if not idx:
                    continue
                wg = []
                for i in idx:
                    g = new_groups[new_portions[i][0]]
                    kind = _gkind(g)
                    wg.append((g[1] * new_portions[i][3], kind[1], kind[2]))
                rc = [caps[new_portions[i][0]] * new_portions[i][6] for i in idx]
                for i, bw in zip(idx, share_weighted_capped(wg, net.l3_caps_gbs[s3], rc)):
                    l3_grant[i] = bw
            else:
                for i in idx:
                    l3_grant[i] = self.l3_grant[old_at_l3[(new_portions[i][0],
                                                           new_portions[i][1])]]
                self.iface_reused += 1

        rates = [_group_rate(new_groups, new_portions, mem_grant, link_grant,
                             l3_grant, g) for g in range(len(new_groups))]
        gated = False
        for i, p in enumerate(new_portions):
            g, w = p[0], p[3]
            n = new_groups[g][1]
            if n == 0:
                continue
            grant = _portion_grant(new_portions, mem_grant, link_grant,
                                   l3_grant, i)
            if grant / (n * w) / p[6] > rates[g] * (1.0 + 1e-9):
                gated = True
        if gated:
            rates, _, _ = share_remote(net, new_groups)
            self.full_solves += 1
        return rates, (new_groups, new_portions, mem_grant, link_grant,
                       l3_grant, rates, gated)

    def commit(self, state):
        (self.groups, self.portions, self.mem_grant, self.link_grant,
         self.l3_grant, self.rates, self.gated) = state


def random_shape(rng, l3_bw=None):
    m = dict(MACHINES["rome"])
    kind = rng.choice(["2x1", "2x2", "2x4", "4x1", "1x4"])
    sockets, per = (int(kind.split("x")[0]), int(kind.split("x")[1]))
    if rng.random() < 0.3:
        m["link_bw"] = rng.choice([2.0, 8.0, 20.0])
    if rng.random() < 0.3:
        m["link_bw_rev"] = rng.choice([2.0, 8.0, 20.0])
    if l3_bw is not None:
        m["l3_bw"] = l3_bw
    scale = None
    if rng.random() < 0.3:
        scale = [rng.choice([0.5, 1.0, 1.25]) for _ in range(sockets * per)]
    return net_of(m, sockets, per, scale)


def random_groups(rng, nd, k):
    levels = [0.0, 0.1, 0.25, 0.5, 1.0]
    out = []
    for _ in range(k):
        out.append((rng.randrange(nd), rng.choice([1, 2, 4, 8]),
                    rng.choice([0.08, 0.3, 0.55, 0.84]),
                    rng.choice([24.0, 32.0, 60.0]),
                    rng.choice(levels)))
    return out


def random_kinded_groups(rng, nd, k):
    """Groups drawing from all three kinds, mirroring the distribution of
    the delta.rs `random_kinded_groups` test helper: ~1/3 L3 (half with no
    DRAM tandem), ~1/6 compute-bound, the rest memory-bound."""
    levels = [0.0, 0.1, 0.25, 0.5, 1.0]
    out = []
    for _ in range(k):
        home = rng.randrange(nd)
        n = rng.choice([1, 2, 4, 8])
        roll = rng.randrange(6)
        if roll in (0, 1):
            f3 = 0.2 + 0.6 * rng.random()
            bs3 = 40.0 + 40.0 * rng.random()
            if rng.random() < 0.5:
                f, bs = 0.0, 0.0
            else:
                f, bs = rng.choice([0.3, 0.55]), rng.choice([24.0, 32.0])
            out.append((home, n, f, bs, 0.0, ("l3", f3, bs3)))
        elif roll == 2:
            out.append((home, n, 0.05, rng.choice([24.0, 32.0]), 0.0, ("comp",)))
        else:
            out.append((home, n, rng.choice([0.08, 0.3, 0.55, 0.84]),
                        rng.choice([24.0, 32.0, 60.0]), rng.choice(levels)))
    return out


def random_move(rng, groups, nd):
    levels = [0.0, 0.1, 0.25, 0.5, 1.0]
    kind = rng.choice(["migrate", "retune", "swap"])
    if kind == "swap" and len(groups) >= 2:
        a, b = rng.sample(range(len(groups)), 2)
        ga, gb = groups[a], groups[b]
        return {a: (gb[0],) + ga[1:], b: (ga[0],) + gb[1:]}
    gi = rng.randrange(len(groups))
    g = groups[gi]
    if kind == "retune":
        return {gi: g[:4] + (rng.choice(levels),) + g[5:]}
    return {gi: (rng.randrange(nd),) + g[1:]}


def random_kinded_move(rng, groups, nd):
    """Only memory-bound groups may retune their remote fraction; L3 and
    compute-bound groups only move home (L3 keeps r == 0)."""
    gi = rng.randrange(len(groups))
    g = groups[gi]
    if _gkind(g) is None and rng.random() < 0.4:
        levels = [0.0, 0.1, 0.25, 0.5, 1.0]
        return {gi: g[:4] + (rng.choice(levels),) + g[5:]}
    return {gi: (rng.randrange(nd),) + g[1:]}


def check_delta_vs_full(cases=300, moves_per_case=8, seed=0xD17A):
    rng = random.Random(seed)
    gated_hits = 0
    reused_total = evald_total = 0
    for case in range(cases):
        net = random_shape(rng)
        nd = len(net.mem_caps)
        groups = random_groups(rng, nd, rng.choice([2, 3, 4, 6, 8]))
        delta = DeltaEval(net, groups)
        ref_rates, _, _ = share_remote(net, groups)
        assert delta.rates == ref_rates, f"case {case}: init mismatch"
        for mv in range(moves_per_case):
            changes = random_move(rng, delta.groups, nd)
            rates, state = delta.eval_move(changes)
            new_groups = list(delta.groups)
            for gi, g in changes.items():
                new_groups[gi] = g
            ref_rates, ref_portions, ref_info = share_remote(net, new_groups)
            assert rates == ref_rates, (
                f"case {case} move {mv}: delta {rates} != full {ref_rates}\n"
                f"  groups {new_groups}")
            if not state[6]:  # ungated: grants must match pass 1 exactly
                assert state[2] == ref_info["mem_grant"], f"case {case} move {mv}: mem"
                assert state[3] == ref_info["link_grant"], f"case {case} move {mv}: link"
            else:
                gated_hits += 1
            delta.commit(state)
        reused_total += delta.iface_reused
        evald_total += delta.iface_evals
    assert gated_hits > 0, "the sweep never exercised the gated fallback"
    assert reused_total > evald_total, (
        f"delta must reuse more interfaces than it evaluates "
        f"(reused {reused_total}, evaluated {evald_total})")
    print(f"[OK] delta == full on {cases} cases x {moves_per_case} moves "
          f"({gated_hits} gated fallbacks, {reused_total} ifaces reused, "
          f"{evald_total} evaluated)")


def check_delta_vs_full_kinded(cases=150, moves_per_case=8, seed=0xCAC4E):
    """The cache-topology extension of the invariant: random walks over
    compositions carrying L3 and compute-bound groups stay bit-identical
    to the full share_remote re-solve (mirrors the delta.rs test
    delta_matches_full_solve_with_l3_and_compute_groups)."""
    rng = random.Random(seed)
    l3_hits = reused_total = evald_total = 0
    for case in range(cases):
        net = random_shape(rng, l3_bw=120.0)
        nd = len(net.mem_caps)
        groups = random_kinded_groups(rng, nd, rng.choice([3, 4, 6, 8]))
        delta = DeltaEval(net, groups)
        ref_rates, _, _ = share_remote(net, groups)
        assert delta.rates == ref_rates, f"case {case}: init mismatch"
        for mv in range(moves_per_case):
            changes = random_kinded_move(rng, delta.groups, nd)
            rates, state = delta.eval_move(changes)
            new_groups = list(delta.groups)
            for gi, g in changes.items():
                new_groups[gi] = g
            ref_rates, _, ref_info = share_remote(net, new_groups)
            assert rates == ref_rates, (
                f"case {case} move {mv}: delta {rates} != full {ref_rates}\n"
                f"  groups {new_groups}")
            if not state[6]:
                assert state[2] == ref_info["mem_grant"], f"case {case} move {mv}: mem"
                assert state[4] == ref_info["l3_grant"], f"case {case} move {mv}: l3"
            delta.commit(state)
            if any(_gkind(g) is not None and _gkind(g)[0] == "l3"
                   for g in (new_groups[gi] for gi in changes)):
                l3_hits += 1
        reused_total += delta.iface_reused
        evald_total += delta.iface_evals
    assert l3_hits > 0, "the sweep never moved an L3 group"
    assert reused_total > 0, "the kinded sweep never reused an interface"
    print(f"[OK] delta == full with L3/compute groups on {cases} cases x "
          f"{moves_per_case} moves ({l3_hits} L3-group moves, "
          f"{reused_total} ifaces reused, {evald_total} evaluated)")


def check_clean_interface_inputs(cases=200, seed=0xFACE):
    """Independent check of the dirty-set rule itself: on every move, the
    (n*w, f, bs*scale, order) inputs of every CLEAN interface are
    bit-identical before and after."""
    rng = random.Random(seed)
    for case in range(cases):
        net = random_shape(rng)
        nd = len(net.mem_caps)
        cap0 = capacity_lines_per_cy(net.m)
        scale = [net.mem_caps[d] / cap0 for d in range(nd)]
        groups = random_groups(rng, nd, rng.choice([2, 4, 8]))
        delta = DeltaEval(net, groups)
        changes = random_move(rng, groups, nd)
        new_groups = list(groups)
        for gi, g in changes.items():
            new_groups[gi] = g
        dirty_mem, dirty_link, _ = delta.dirty_set(changes)
        old_p = _expand_portions(net, groups)
        new_p = _expand_portions(net, new_groups)

        def iface_inputs(portions, gs, d=None, l=None):
            sel = [p for p in portions if (p[1] == d if d is not None else p[2] == l)]
            return [(p[0], p[1], gs[p[0]][1] * p[3], gs[p[0]][2],
                     gs[p[0]][3] * scale[p[1]]) for p in sel]

        for d in range(nd):
            if d in dirty_mem:
                continue
            assert iface_inputs(old_p, groups, d=d) == iface_inputs(new_p, new_groups, d=d), (
                f"case {case}: clean mem iface {d} inputs drifted")
        for l in range(len(net.links)):
            if l in dirty_link:
                continue
            assert iface_inputs(old_p, groups, l=l) == iface_inputs(new_p, new_groups, l=l), (
                f"case {case}: clean link {l} inputs drifted")
    print(f"[OK] clean-interface inputs bit-stable on {cases} random moves")


if __name__ == "__main__":
    check_clean_interface_inputs()
    check_delta_vs_full()
    check_delta_vs_full_kinded()
    print("optimizer mirror: all checks passed")
