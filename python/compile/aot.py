"""AOT pipeline: lower the L2 JAX model to HLO *text* artifacts.

HLO text (NOT ``lowered.compile()`` / serialized protos) is the interchange
format: jax >= 0.5 emits HloModuleProto with 64-bit instruction ids which the
``xla`` crate's xla_extension 0.5.1 rejects (``proto.id() <= INT_MAX``); the
text parser reassigns ids and round-trips cleanly. See
/opt/xla-example/gen_hlo.py.

Artifacts (written to ``artifacts/``):

* ``contention_sim.hlo.txt`` — batched fluid contention simulation
  (B=64 configs x N=24 cores, 1 warm-up + 3 measure chunks of 4096 cycles).
  Inputs: d, c, win [B,N] f32; cap [B,1] f32. Output: served [B,N] f32.
* ``analytic_model.hlo.txt`` — batched Eqs. (4)+(5) evaluation, 256 cases.
  Inputs: n1, f1, bs1, n2, f2, bs2 [256] f32. Outputs: per-core bandwidths.
* ``artifacts.meta`` — shapes/cycle counts the Rust runtime needs.

Usage: ``python -m compile.aot --out-dir ../artifacts`` (from ``python/``).
"""

import argparse
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from compile.kernels.contention import BATCH, CHUNK_CYCLES, N_CORES
from compile import model

WARMUP_CHUNKS = 1
MEASURE_CHUNKS = 3
ANALYTIC_BATCH = 256


def to_hlo_text(lowered) -> str:
    """Convert a jax lowering to XLA HLO text (see module docstring)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def lower_contention_sim() -> str:
    """Lower the batched contention simulation."""
    plane = jax.ShapeDtypeStruct((BATCH, N_CORES), jnp.float32)
    cap = jax.ShapeDtypeStruct((BATCH, 1), jnp.float32)

    def fn(d, c, win, cap):
        return (
            model.simulate(
                d, c, win, cap,
                warmup_chunks=WARMUP_CHUNKS,
                measure_chunks=MEASURE_CHUNKS,
                cycles=CHUNK_CYCLES,
            ),
        )

    return to_hlo_text(jax.jit(fn).lower(plane, plane, plane, cap))


def lower_analytic() -> str:
    """Lower the batched analytic model."""
    vec = jax.ShapeDtypeStruct((ANALYTIC_BATCH,), jnp.float32)

    def fn(n1, f1, bs1, n2, f2, bs2):
        return model.analytic_two_group(n1, f1, bs1, n2, f2, bs2)

    return to_hlo_text(jax.jit(fn).lower(vec, vec, vec, vec, vec, vec))


def write_meta(out_dir: str) -> None:
    """Emit the artifact geometry for the Rust runtime (key=value lines)."""
    meta = {
        "batch": BATCH,
        "n_cores": N_CORES,
        "chunk_cycles": CHUNK_CYCLES,
        "warmup_chunks": WARMUP_CHUNKS,
        "measure_chunks": MEASURE_CHUNKS,
        "measure_cycles": MEASURE_CHUNKS * CHUNK_CYCLES,
        "analytic_batch": ANALYTIC_BATCH,
    }
    with open(os.path.join(out_dir, "artifacts.meta"), "w") as f:
        for k, v in meta.items():
            f.write(f"{k} = {v}\n")


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts")
    args = ap.parse_args()
    os.makedirs(args.out_dir, exist_ok=True)

    sim = lower_contention_sim()
    path = os.path.join(args.out_dir, "contention_sim.hlo.txt")
    with open(path, "w") as f:
        f.write(sim)
    print(f"wrote {len(sim)} chars to {path}")

    ana = lower_analytic()
    path = os.path.join(args.out_dir, "analytic_model.hlo.txt")
    with open(path, "w") as f:
        f.write(ana)
    print(f"wrote {len(ana)} chars to {path}")

    write_meta(args.out_dir)
    print(f"wrote {os.path.join(args.out_dir, 'artifacts.meta')}")


if __name__ == "__main__":
    main()
