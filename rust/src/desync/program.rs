//! Phase programs: what each MPI rank executes.

use crate::kernels::KernelId;

/// Synchronization semantics attached to a phase.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SyncKind {
    /// No synchronization: start as soon as the previous phase ends.
    None,
    /// Nonblocking point-to-point halo dependency (SpMV/SymGS): the phase
    /// cannot *start* before both neighbor ranks have finished their
    /// previous phase (periodic neighbor topology).
    Neighbors,
    /// Global collective (MPI_Allreduce): the phase completes only after
    /// every rank has reached it.
    Global,
}

/// One phase of the program.
#[derive(Debug, Clone, PartialEq)]
pub enum Phase {
    /// A memory-bound loop kernel moving `volume_bytes` over the memory
    /// interface per rank.
    Kernel {
        /// Which Table II kernel characterizes the traffic.
        kernel: KernelId,
        /// Memory data volume per rank, bytes.
        volume_bytes: f64,
        /// Synchronization before the kernel may start.
        sync: SyncKind,
        /// Label used in traces ("DDOT2#1", "SymGS-pre", ...).
        label: &'static str,
    },
    /// A global collective with the given base cost (seconds).
    Allreduce {
        /// Time the collective itself takes once all ranks arrived.
        cost_s: f64,
        /// Trace label.
        label: &'static str,
    },
    /// Idle time (explicitly injected delay, distinct from noise).
    Idle {
        /// Duration in seconds.
        duration_s: f64,
        /// Trace label.
        label: &'static str,
    },
}

impl Phase {
    /// Trace label of the phase.
    pub fn label(&self) -> &'static str {
        match self {
            Phase::Kernel { label, .. } => label,
            Phase::Allreduce { label, .. } => label,
            Phase::Idle { label, .. } => label,
        }
    }
}

/// A rank's program: a phase list executed `iterations` times.
#[derive(Debug, Clone)]
pub struct Program {
    /// Phases of one iteration.
    pub phases: Vec<Phase>,
    /// Number of iterations.
    pub iterations: usize,
}

impl Program {
    /// Total number of phase instances.
    pub fn total_phases(&self) -> usize {
        self.phases.len() * self.iterations
    }

    /// Phase for a given flat index.
    pub fn phase(&self, flat: usize) -> Option<&Phase> {
        if flat >= self.total_phases() {
            None
        } else {
            Some(&self.phases[flat % self.phases.len()])
        }
    }
}

/// Which HPCG variant to build (Sect. I-A).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum HpcgVariant {
    /// Plain HPCG: DDOTs are followed by MPI_Allreduce (Fig. 1).
    Plain,
    /// Modified HPCG: all reductions removed, desynchronized states
    /// survive (Fig. 3).
    Modified,
}

/// Build a simplified HPCG iteration at problem size `nx`³ per rank.
///
/// Sparse kernels are mapped onto Table II streaming proxies with matching
/// traffic character (documented substitution, DESIGN.md §2): the paper
/// itself shows only `f` and `b_s` matter for bandwidth sharing.
///
/// Phase structure (one CG iteration, condensed to the Fig. 3 sandwich
/// order): SymGS (halo) → **DDOT2#1** [→ Allreduce] → SpMV (halo) →
/// **DDOT2#2** [→ Allreduce] → DAXPY#1 → DAXPY#2 → **DDOT1**
/// [→ Allreduce] → WAXPBY → next iteration.
///
/// * DDOT2#1 sits between SymGS and SpMV: its stragglers overlap the halo
///   *wait* of early SpMV entrants → resynchronization (Fig. 3a, negative
///   skew).
/// * DDOT2#2 is followed by DAXPY (higher f) → desync amplification
///   (Fig. 3b, positive skew); DDOT1 by WAXPBY likewise.
///
/// The SymGS volume is ~20x the DDOT2 volume, matching the runtime ratio
/// reported for Fig. 1.
pub fn hpcg_program(variant: HpcgVariant, nx: usize, iterations: usize) -> Program {
    let n = (nx * nx * nx) as f64; // grid points per rank
    let vec_bytes = n * 8.0;

    // DDOT2 reads two vectors.
    let ddot2 = 2.0 * vec_bytes;
    // DDOT1 reads one vector.
    let ddot1 = vec_bytes;
    // DAXPY: 2 reads + 1 write-allocate-free write (in-place) ≈ 3 streams.
    let daxpy = 3.0 * vec_bytes;
    // WAXPBY: 4 streams.
    let waxpby = 4.0 * vec_bytes;
    // 27-point CRS SpMV: values+cols (12 B/nnz) + vectors ≈ 27*12+3*8 B/row.
    let spmv = n * (27.0 * 12.0 + 24.0);
    // SymGS fwd+bwd sweep over the same matrix: ~2x SpMV traffic (the
    // "~20x DDOT2 runtime" of Sect. I-A comes out of this volume).
    let symgs = 2.0 * spmv;

    let mut phases = vec![
        Phase::Kernel { kernel: KernelId::Schoenauer, volume_bytes: symgs, sync: SyncKind::Neighbors, label: "SymGS" },
        Phase::Kernel { kernel: KernelId::Ddot2, volume_bytes: ddot2, sync: SyncKind::None, label: "DDOT2#1" },
    ];
    if variant == HpcgVariant::Plain {
        phases.push(Phase::Allreduce { cost_s: 15e-6, label: "Allreduce#1" });
    }
    phases.extend([
        Phase::Kernel { kernel: KernelId::Add, volume_bytes: spmv, sync: SyncKind::Neighbors, label: "SpMV" },
        Phase::Kernel { kernel: KernelId::Ddot2, volume_bytes: ddot2, sync: SyncKind::None, label: "DDOT2#2" },
    ]);
    if variant == HpcgVariant::Plain {
        phases.push(Phase::Allreduce { cost_s: 15e-6, label: "Allreduce#2" });
    }
    phases.extend([
        Phase::Kernel { kernel: KernelId::Daxpy, volume_bytes: daxpy, sync: SyncKind::None, label: "DAXPY#1" },
        Phase::Kernel { kernel: KernelId::Daxpy, volume_bytes: daxpy, sync: SyncKind::None, label: "DAXPY#2" },
        Phase::Kernel { kernel: KernelId::Ddot1, volume_bytes: ddot1, sync: SyncKind::None, label: "DDOT1" },
    ]);
    if variant == HpcgVariant::Plain {
        phases.push(Phase::Allreduce { cost_s: 15e-6, label: "Allreduce#3" });
    }
    phases.push(Phase::Kernel { kernel: KernelId::Waxpby, volume_bytes: waxpby, sync: SyncKind::None, label: "WAXPBY" });

    Program { phases, iterations }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn plain_has_allreduces_modified_does_not() {
        let plain = hpcg_program(HpcgVariant::Plain, 32, 2);
        let modified = hpcg_program(HpcgVariant::Modified, 32, 2);
        let count = |p: &Program| {
            p.phases.iter().filter(|ph| matches!(ph, Phase::Allreduce { .. })).count()
        };
        assert_eq!(count(&plain), 3);
        assert_eq!(count(&modified), 0);
    }

    #[test]
    fn symgs_volume_dominates_ddot2() {
        // Paper: SymGS runtime ~20x DDOT2 (Sect. I-A). Volumes are the
        // first-order proxy for runtime at equal bandwidth.
        let p = hpcg_program(HpcgVariant::Plain, 160, 1);
        let vol = |label: &str| {
            p.phases
                .iter()
                .find_map(|ph| match ph {
                    Phase::Kernel { volume_bytes, label: l, .. } if *l == label => Some(*volume_bytes),
                    _ => None,
                })
                .unwrap()
        };
        let ratio = vol("SymGS") / vol("DDOT2#1");
        assert!((15.0..60.0).contains(&ratio), "ratio {ratio}");
    }

    #[test]
    fn flat_phase_indexing_wraps_iterations() {
        let p = hpcg_program(HpcgVariant::Modified, 16, 3);
        let per_iter = p.phases.len();
        assert_eq!(p.total_phases(), 3 * per_iter);
        assert_eq!(p.phase(per_iter), Some(&p.phases[0]));
        assert_eq!(p.phase(3 * per_iter), None);
    }
}
