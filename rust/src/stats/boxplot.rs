//! Box-plot summaries (Fig. 8: whiskers = min/max, box = 2nd+3rd quartile,
//! median marked).

/// Five-number summary of a sample.
#[derive(Debug, Clone, Copy)]
pub struct BoxSummary {
    /// Minimum (lower whisker).
    pub min: f64,
    /// First quartile (box bottom).
    pub q1: f64,
    /// Median.
    pub median: f64,
    /// Third quartile (box top).
    pub q3: f64,
    /// Maximum (upper whisker).
    pub max: f64,
}

/// Linear-interpolation quantile of a sorted sample.
fn quantile(sorted: &[f64], q: f64) -> f64 {
    if sorted.is_empty() {
        return f64::NAN;
    }
    if sorted.len() == 1 {
        return sorted[0];
    }
    let pos = q * (sorted.len() - 1) as f64;
    let lo = pos.floor() as usize;
    let hi = pos.ceil() as usize;
    let frac = pos - lo as f64;
    sorted[lo] * (1.0 - frac) + sorted[hi] * frac
}

impl BoxSummary {
    /// Compute the five-number summary.
    pub fn of(xs: &[f64]) -> Self {
        let mut sorted = xs.to_vec();
        sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
        BoxSummary {
            min: *sorted.first().unwrap_or(&f64::NAN),
            q1: quantile(&sorted, 0.25),
            median: quantile(&sorted, 0.5),
            q3: quantile(&sorted, 0.75),
            max: *sorted.last().unwrap_or(&f64::NAN),
        }
    }

    /// Render a compact one-line ASCII box plot scaled to `[0, scale_max]`
    /// over `width` characters (used by the Fig. 8 report).
    pub fn render_ascii(&self, scale_max: f64, width: usize) -> String {
        let col = |v: f64| ((v / scale_max) * (width as f64 - 1.0)).round().clamp(0.0, width as f64 - 1.0) as usize;
        let mut line = vec![' '; width];
        for i in col(self.min)..=col(self.max) {
            line[i] = '-';
        }
        for i in col(self.q1)..=col(self.q3) {
            line[i] = '=';
        }
        line[col(self.median)] = '|';
        line.into_iter().collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn quartiles_of_known_sample() {
        let b = BoxSummary::of(&[1.0, 2.0, 3.0, 4.0, 5.0]);
        assert_eq!(b.min, 1.0);
        assert_eq!(b.median, 3.0);
        assert_eq!(b.max, 5.0);
        assert!((b.q1 - 2.0).abs() < 1e-12);
        assert!((b.q3 - 4.0).abs() < 1e-12);
    }

    #[test]
    fn ascii_render_has_median_marker() {
        let b = BoxSummary::of(&[0.01, 0.02, 0.03, 0.05, 0.08]);
        let line = b.render_ascii(0.1, 40);
        assert_eq!(line.len(), 40);
        assert!(line.contains('|'));
        assert!(line.contains('='));
    }
}
